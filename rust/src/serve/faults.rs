//! Deterministic fault injection for the serve subsystem.
//!
//! Two halves:
//!
//! * **Server-side** — [`FaultPlan`], threaded into the dispatcher:
//!   forced kernel panics on chosen batch sequence numbers (exercising
//!   the `catch_unwind` isolation and quarantine paths) and a per-batch
//!   stall (widening the dispatch window so deadline/hot-swap races
//!   become testable). The plan is always compiled but inert by default
//!   (`FaultPlan::default().is_inert()`), so production dispatch pays two
//!   predictable branches; hidden CLI flags (`--inject-panic-every`,
//!   `--stall-ms`) arm it for the smoke leg.
//! * **Client-side** — frame mutilators ([`truncate_frame`],
//!   [`corrupt_byte`], [`oversize_len`]), a slow-loris [`SlowWriter`]
//!   that dribbles bytes with a delay, and an in-memory [`pipe`] so
//!   integration tests and the bench drive a real reader/writer pair
//!   without sockets.
//!
//! Everything here is deterministic: the same plan against the same
//! request stream produces the same faults, so every failure the harness
//! finds is replayable.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Server-side fault schedule, keyed by the dispatcher's batch sequence
/// number (the first batch is seq 1).
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Panic on exactly these batch sequence numbers.
    pub panic_on_batches: Vec<u64>,
    /// Panic on every N-th batch (`Some(3)` = seq 3, 6, 9, ...).
    pub panic_every: Option<u64>,
    /// Sleep this long inside every dispatch, before deadlines are
    /// checked — widens the window in which deadlines expire and
    /// reloads land mid-batch.
    pub stall_ms: u64,
}

impl FaultPlan {
    /// True when the plan injects nothing (the production configuration).
    pub fn is_inert(&self) -> bool {
        self.panic_on_batches.is_empty() && self.panic_every.is_none() && self.stall_ms == 0
    }

    /// Should batch `seq` be killed with a forced panic?
    pub fn should_panic(&self, seq: u64) -> bool {
        if self.panic_on_batches.contains(&seq) {
            return true;
        }
        match self.panic_every {
            Some(n) if n > 0 => seq % n == 0,
            _ => false,
        }
    }

    /// The dispatch stall, if any.
    pub fn stall(&self) -> Option<Duration> {
        (self.stall_ms > 0).then(|| Duration::from_millis(self.stall_ms))
    }
}

/// Keep only the first `keep` bytes of a frame (truncation mid-header or
/// mid-body, depending on `keep`).
pub fn truncate_frame(frame: &[u8], keep: usize) -> Vec<u8> {
    frame[..keep.min(frame.len())].to_vec()
}

/// Flip every bit of the byte at `at`.
pub fn corrupt_byte(frame: &[u8], at: usize) -> Vec<u8> {
    let mut out = frame.to_vec();
    if let Some(b) = out.get_mut(at) {
        *b ^= 0xFF;
    }
    out
}

/// Rewrite the header length field to a lying huge value, keeping the
/// original body — the parser must reject on the length alone, before
/// allocating.
pub fn oversize_len(frame: &[u8]) -> Vec<u8> {
    let mut out = frame.to_vec();
    if out.len() >= 8 {
        out[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
    }
    out
}

/// Slow-loris writer: forwards at most `chunk` bytes per `write`, with a
/// `delay` sleep before each one. Wrapping a client's stream in this
/// verifies the reader survives arbitrarily fragmented frames.
pub struct SlowWriter<W: Write> {
    pub inner: W,
    pub chunk: usize,
    pub delay: Duration,
}

impl<W: Write> Write for SlowWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        std::thread::sleep(self.delay);
        let n = buf.len().min(self.chunk.max(1));
        self.inner.write(&buf[..n])
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

struct PipeState {
    buf: VecDeque<u8>,
    writers: usize,
}

struct PipeShared {
    state: Mutex<PipeState>,
    readable: Condvar,
}

/// Write half of an in-memory pipe. Cloning adds a writer; the reader
/// sees EOF only after every clone is dropped.
pub struct PipeWriter {
    shared: Arc<PipeShared>,
}

/// Read half of an in-memory pipe. Blocks until bytes arrive or all
/// writers hang up (then returns `Ok(0)` — EOF).
pub struct PipeReader {
    shared: Arc<PipeShared>,
}

/// An in-memory byte pipe with blocking reads and EOF-on-hangup — the
/// stand-in for a socket in the integration tests and the bench.
pub fn pipe() -> (PipeWriter, PipeReader) {
    let shared = Arc::new(PipeShared {
        state: Mutex::new(PipeState { buf: VecDeque::new(), writers: 1 }),
        readable: Condvar::new(),
    });
    (PipeWriter { shared: Arc::clone(&shared) }, PipeReader { shared })
}

impl Clone for PipeWriter {
    fn clone(&self) -> PipeWriter {
        self.shared.state.lock().unwrap().writers += 1;
        PipeWriter { shared: Arc::clone(&self.shared) }
    }
}

impl Drop for PipeWriter {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.writers -= 1;
        if st.writers == 0 {
            drop(st);
            self.shared.readable.notify_all();
        }
    }
}

impl Write for PipeWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let mut st = self.shared.state.lock().unwrap();
        st.buf.extend(buf.iter().copied());
        drop(st);
        self.shared.readable.notify_all();
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl Read for PipeReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if !st.buf.is_empty() {
                let n = buf.len().min(st.buf.len());
                for slot in buf.iter_mut().take(n) {
                    *slot = st.buf.pop_front().unwrap();
                }
                return Ok(n);
            }
            if st.writers == 0 {
                return Ok(0);
            }
            st = self.shared.readable.wait(st).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_inert() {
        let plan = FaultPlan::default();
        assert!(plan.is_inert());
        assert!(!plan.should_panic(1));
        assert!(plan.stall().is_none());
    }

    #[test]
    fn panic_schedule_is_deterministic() {
        let plan = FaultPlan {
            panic_on_batches: vec![2, 5],
            panic_every: Some(4),
            stall_ms: 0,
        };
        let fired: Vec<u64> = (1..=10).filter(|&s| plan.should_panic(s)).collect();
        assert_eq!(fired, vec![2, 4, 5, 8]);
    }

    #[test]
    fn frame_mutilators_shape_bytes_as_documented() {
        let frame: Vec<u8> = (0..16).collect();
        assert_eq!(truncate_frame(&frame, 3), vec![0, 1, 2]);
        assert_eq!(truncate_frame(&frame, 99).len(), 16);
        let c = corrupt_byte(&frame, 2);
        assert_eq!(c[2], 2 ^ 0xFF);
        assert_eq!(c[3], 3);
        let o = oversize_len(&frame);
        assert_eq!(&o[4..8], &u32::MAX.to_le_bytes());
        assert_eq!(&o[8..], &frame[8..]);
    }

    #[test]
    fn pipe_blocks_then_delivers_and_eofs_on_hangup() {
        let (mut w, mut r) = pipe();
        let reader = std::thread::spawn(move || {
            let mut all = Vec::new();
            r.read_to_end(&mut all).unwrap();
            all
        });
        w.write_all(b"hello ").unwrap();
        let w2 = w.clone();
        drop(w);
        // second writer keeps the pipe open
        {
            let mut w2 = w2;
            w2.write_all(b"world").unwrap();
        }
        assert_eq!(reader.join().unwrap(), b"hello world");
    }

    #[test]
    fn slow_writer_fragments_but_delivers_everything() {
        let (w, mut r) = pipe();
        let mut slow = SlowWriter { inner: w, chunk: 3, delay: Duration::from_millis(1) };
        let payload: Vec<u8> = (0..32).collect();
        let writer = std::thread::spawn(move || {
            slow.write_all(&payload).unwrap();
        });
        let mut got = Vec::new();
        r.read_to_end(&mut got).unwrap();
        writer.join().unwrap();
        assert_eq!(got, (0..32).collect::<Vec<u8>>());
    }
}
