//! Named model registry with atomic hot swap and failure quarantine.
//!
//! Each served model lives in a [`ModelSlot`]: the current
//! [`KMedoidsModel`] sits behind an `Arc` that is swapped atomically on
//! reload (lock held only for the pointer swap, never during the disk
//! load), so in-flight batches keep computing against the `Arc` they
//! cloned at admission while new batches see the new model — the
//! arc-swap pattern without the crate.
//!
//! Quarantine: when a batch against a slot panics, the dispatcher calls
//! [`ModelSlot::record_panic`]; after `threshold` *consecutive* failures
//! the slot is quarantined and fast-rejects predict requests with the
//! `Quarantined` error code until a successful [`ModelSlot::reload`]
//! clears it. A successful batch resets the consecutive-failure count.

use crate::error::{Error, Result};
use crate::model::KMedoidsModel;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex};

/// One immutable generation of a served model. Batches hold an
/// `Arc<LoadedModel>` for their whole lifetime, so a reload can never
/// change the bytes a batch computes against.
pub struct LoadedModel {
    pub model: KMedoidsModel,
    /// Monotonic reload generation (1 = the initial load).
    pub version: u64,
}

/// A named slot in the registry: current model generation plus failure
/// accounting.
pub struct ModelSlot {
    name: String,
    path: PathBuf,
    current: Mutex<Arc<LoadedModel>>,
    consecutive_failures: AtomicU32,
    quarantined: AtomicBool,
}

impl ModelSlot {
    fn open(name: &str, path: &Path) -> Result<ModelSlot> {
        let model = KMedoidsModel::load(path)?;
        Ok(ModelSlot {
            name: name.to_string(),
            path: path.to_path_buf(),
            current: Mutex::new(Arc::new(LoadedModel { model, version: 1 })),
            consecutive_failures: AtomicU32::new(0),
            quarantined: AtomicBool::new(false),
        })
    }

    /// Registry name of this slot.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The file the slot (re)loads from.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The current model generation. Lock held only for the clone.
    pub fn current(&self) -> Arc<LoadedModel> {
        Arc::clone(&self.current.lock().unwrap())
    }

    /// Whether the slot is fast-rejecting requests after repeated
    /// failures.
    pub fn is_quarantined(&self) -> bool {
        self.quarantined.load(Ordering::Acquire)
    }

    /// Reload from disk and swap atomically. The disk read happens
    /// outside the slot lock; in-flight batches finish on the old `Arc`.
    /// A successful reload clears quarantine; a failed one changes
    /// nothing.
    pub fn reload(&self) -> Result<u64> {
        let model = KMedoidsModel::load(&self.path).map_err(|e| {
            Error::model(format!("reloading {:?} from {:?}: {e}", self.name, self.path))
        })?;
        let mut cur = self.current.lock().unwrap();
        let version = cur.version + 1;
        *cur = Arc::new(LoadedModel { model, version });
        drop(cur);
        self.consecutive_failures.store(0, Ordering::Release);
        self.quarantined.store(false, Ordering::Release);
        Ok(version)
    }

    /// Record a batch panic against this slot. Returns `true` when this
    /// failure is the one that newly trips the quarantine.
    pub fn record_panic(&self, threshold: u32) -> bool {
        let n = self.consecutive_failures.fetch_add(1, Ordering::AcqRel) + 1;
        if n >= threshold && !self.quarantined.swap(true, Ordering::AcqRel) {
            return true;
        }
        false
    }

    /// Record a successful batch: resets the consecutive-failure count.
    pub fn record_success(&self) {
        self.consecutive_failures.store(0, Ordering::Release);
    }
}

/// The set of served models, keyed by name.
pub struct Registry {
    slots: BTreeMap<String, Arc<ModelSlot>>,
}

impl Registry {
    /// Load every `(name, path)` spec. Duplicate names and unreadable
    /// files are startup errors — a server with a half-loaded registry
    /// would silently shed traffic.
    pub fn open(specs: &[(String, PathBuf)]) -> Result<Registry> {
        if specs.is_empty() {
            return Err(Error::invalid_argument(
                "serve needs at least one model (name=path.bpmodel)",
            ));
        }
        let mut slots = BTreeMap::new();
        for (name, path) in specs {
            if name.is_empty() {
                return Err(Error::invalid_argument(format!(
                    "empty model name for {path:?}"
                )));
            }
            if name.len() > super::protocol::MAX_NAME {
                return Err(Error::invalid_argument(format!(
                    "model name {name:?} exceeds {} bytes",
                    super::protocol::MAX_NAME
                )));
            }
            let slot = ModelSlot::open(name, path)
                .map_err(|e| Error::model(format!("loading {name:?} from {path:?}: {e}")))?;
            if slots.insert(name.clone(), Arc::new(slot)).is_some() {
                return Err(Error::invalid_argument(format!(
                    "duplicate model name {name:?}"
                )));
            }
        }
        Ok(Registry { slots })
    }

    /// Look up a slot by name.
    pub fn get(&self, name: &str) -> Option<&Arc<ModelSlot>> {
        self.slots.get(name)
    }

    /// Slot names in sorted order.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.slots.keys().map(String::as_str)
    }

    /// All slots in name order.
    pub fn slots(&self) -> impl Iterator<Item = &Arc<ModelSlot>> {
        self.slots.values()
    }

    /// Reload one model (nonempty `name`) or every model (empty), and
    /// report per-slot outcomes as `name: vN` / `name: error ...` lines.
    /// A failed reload leaves the old generation serving.
    pub fn reload(&self, name: &str) -> Result<String> {
        if !name.is_empty() {
            let slot = self
                .get(name)
                .ok_or_else(|| Error::invalid_argument(format!("unknown model {name:?}")))?;
            let v = slot.reload()?;
            return Ok(format!("{name}: v{v}"));
        }
        let mut lines = Vec::new();
        for slot in self.slots() {
            match slot.reload() {
                Ok(v) => lines.push(format!("{}: v{v}", slot.name())),
                Err(e) => lines.push(format!("{}: error {e}", slot.name())),
            }
        }
        Ok(lines.join("\n"))
    }

    /// The `list-models` response text: one
    /// `name kind k dim version` line per slot.
    pub fn describe(&self) -> String {
        let mut lines = Vec::new();
        for slot in self.slots() {
            let cur = slot.current();
            let kind = cur.model.medoid_points().kind();
            let dim = cur
                .model
                .dim()
                .map(|d| d.to_string())
                .unwrap_or_else(|| "-".into());
            lines.push(format!(
                "{} {kind} k={} dim={dim} v{}{}",
                slot.name(),
                cur.model.k(),
                cur.version,
                if slot.is_quarantined() { " quarantined" } else { "" },
            ));
        }
        lines.join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::model::Fit;
    use crate::util::rng::Rng;

    fn save_model(dir: &Path, name: &str, seed: u64) -> PathBuf {
        let ds = synthetic::gmm(&mut Rng::seed_from(seed), 24, 6, 2, 3.0);
        let model = Fit::banditpam().k(2).seed(seed).fit(&ds).unwrap();
        let path = dir.join(format!("{name}.bpmodel"));
        model.save(&path).unwrap();
        path
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("bp_registry_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn reload_swaps_atomically_and_inflight_holds_old_arc() {
        let dir = tmpdir("swap");
        let path = save_model(&dir, "m", 1);
        let reg = Registry::open(&[("m".into(), path.clone())]).unwrap();
        let slot = reg.get("m").unwrap();
        let inflight = slot.current();
        assert_eq!(inflight.version, 1);

        // Overwrite the file with a differently-seeded model, reload.
        save_model(&dir, "m", 99);
        let report = reg.reload("m").unwrap();
        assert_eq!(report, "m: v2");
        assert_eq!(slot.current().version, 2);
        // The in-flight generation is untouched.
        assert_eq!(inflight.version, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_reload_leaves_old_generation_serving() {
        let dir = tmpdir("failedreload");
        let path = save_model(&dir, "m", 1);
        let reg = Registry::open(&[("m".into(), path.clone())]).unwrap();
        std::fs::write(&path, b"garbage").unwrap();
        assert!(reg.reload("m").is_err());
        let slot = reg.get("m").unwrap();
        assert_eq!(slot.current().version, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quarantine_trips_after_threshold_and_reload_clears_it() {
        let dir = tmpdir("quarantine");
        let path = save_model(&dir, "m", 1);
        let reg = Registry::open(&[("m".into(), path)]).unwrap();
        let slot = reg.get("m").unwrap();

        assert!(!slot.record_panic(3));
        assert!(!slot.record_panic(3));
        // A success in between resets the streak.
        slot.record_success();
        assert!(!slot.record_panic(3));
        assert!(!slot.record_panic(3));
        assert!(slot.record_panic(3), "third consecutive failure trips");
        assert!(slot.is_quarantined());
        // Tripping again reports false (already quarantined).
        assert!(!slot.record_panic(3));

        slot.reload().unwrap();
        assert!(!slot.is_quarantined());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_rejects_bad_specs() {
        let dir = tmpdir("specs");
        let path = save_model(&dir, "m", 1);
        assert_eq!(Registry::open(&[]).unwrap_err().kind(), "invalid_argument");
        assert_eq!(
            Registry::open(&[(String::new(), path.clone())]).unwrap_err().kind(),
            "invalid_argument"
        );
        assert_eq!(
            Registry::open(&[
                ("m".into(), path.clone()),
                ("m".into(), path.clone()),
            ])
            .unwrap_err()
            .kind(),
            "invalid_argument"
        );
        assert_eq!(
            Registry::open(&[("m".into(), dir.join("missing.bpmodel"))])
                .unwrap_err()
                .kind(),
            "model"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn describe_lists_models() {
        let dir = tmpdir("describe");
        let path = save_model(&dir, "m", 1);
        let reg = Registry::open(&[("m".into(), path)]).unwrap();
        let text = reg.describe();
        assert!(text.starts_with("m dense k=2"), "{text}");
        assert!(text.contains("v1"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
