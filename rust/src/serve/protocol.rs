//! The length-prefixed binary wire protocol of the `serve` subcommand.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! magic    2  b"BQ"
//! version  1  u8 = 1
//! kind     1  u8 (request/response tag, see below)
//! length   4  u32 body length (<= MAX_FRAME_BODY)
//! body     length bytes
//! ```
//!
//! Every body begins with a `u64` request id chosen by the client; the
//! response echoes it, so clients may pipeline requests freely.
//!
//! Request kinds: 1 predict, 2 ping, 3 stats, 4 reload, 5 shutdown,
//! 6 list-models, 7 metrics. Response kinds: 0x81 assignments, 0x82 error,
//! 0x83 pong, 0x84 stats, 0x85 reload-ack, 0x86 shutdown-ack,
//! 0x87 model-list, 0x88 metrics (Prometheus text exposition). The
//! full byte-level spec (with the body grammars) lives in `rust/SERVE.md`,
//! and the golden fixtures under `tests/fixtures/serve/` pin it.
//!
//! # Hostile input
//!
//! The parser follows the model-reader discipline (`model/format.rs`):
//! every length is checked against the bytes actually present **before**
//! any allocation, every reject is a clean error (never a panic), and
//! trailing bytes after a body grammar are rejected. Two error tiers:
//!
//! * **Connection-fatal** ([`read_frame`] `Err`): bad magic/version, a
//!   body length beyond [`MAX_FRAME_BODY`], or EOF mid-frame. Once framing
//!   is lost, resynchronization is impossible — the server sends a
//!   best-effort error (id 0) and closes.
//! * **Recoverable** ([`parse_request`] `Err`): the frame was well-framed
//!   but its body violates the grammar. The failure carries whatever id
//!   was readable so the error response can echo it; the connection
//!   continues.

use crate::data::sparse::CsrMatrix;
use crate::data::Points;
use crate::util::matrix::Matrix;
use std::fmt;
use std::io::{Read, Write};

/// Frame magic: "BQ" (banditpam query).
pub const MAGIC: [u8; 2] = *b"BQ";
/// Protocol version.
pub const VERSION: u8 = 1;
/// Hard cap on a frame body; a lying length field beyond this is
/// connection-fatal before any allocation happens.
pub const MAX_FRAME_BODY: usize = 64 << 20;
/// Cap on a model-name field.
pub const MAX_NAME: usize = 256;
/// Cap on an error-message field (longer messages are truncated on encode).
pub const MAX_ERROR_MSG: usize = 1024;

/// Request frame kinds (the `kind` header byte).
pub mod req {
    pub const PREDICT: u8 = 1;
    pub const PING: u8 = 2;
    pub const STATS: u8 = 3;
    pub const RELOAD: u8 = 4;
    pub const SHUTDOWN: u8 = 5;
    pub const LIST_MODELS: u8 = 6;
    pub const METRICS: u8 = 7;
}

/// Response frame kinds (the `kind` header byte; high bit set).
pub mod resp {
    pub const ASSIGNMENTS: u8 = 0x81;
    pub const ERROR: u8 = 0x82;
    pub const PONG: u8 = 0x83;
    pub const STATS: u8 = 0x84;
    pub const RELOAD_ACK: u8 = 0x85;
    pub const SHUTDOWN_ACK: u8 = 0x86;
    pub const MODEL_LIST: u8 = 0x87;
    pub const METRICS: u8 = 0x88;
}

/// A parsed request frame.
#[derive(Debug, Clone)]
pub enum Request {
    Predict(PredictRequest),
    Ping { id: u64 },
    Stats { id: u64 },
    /// Reload the named model from disk (empty name = every model).
    Reload { id: u64, name: String },
    Shutdown { id: u64 },
    ListModels { id: u64 },
    /// Scrape the process metrics (Prometheus text exposition).
    Metrics { id: u64 },
}

impl Request {
    /// The client-chosen request id.
    pub fn id(&self) -> u64 {
        match self {
            Request::Predict(p) => p.id,
            Request::Ping { id }
            | Request::Stats { id }
            | Request::Reload { id, .. }
            | Request::Shutdown { id }
            | Request::ListModels { id }
            | Request::Metrics { id } => *id,
        }
    }
}

/// A predict request: assign `queries` against the named model.
#[derive(Debug, Clone)]
pub struct PredictRequest {
    pub id: u64,
    /// Registry name of the target model.
    pub model: String,
    /// Per-request deadline in milliseconds from admission (0 = none).
    pub deadline_ms: u32,
    /// The query points (dense or CSR; finite values only).
    pub queries: Points,
}

/// Typed error codes carried by error response frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed body, storage/dimension mismatch, unknown frame kind.
    BadRequest = 1,
    /// The named model is not in the registry.
    UnknownModel = 2,
    /// The request's deadline expired before its batch was dispatched.
    DeadlineExceeded = 3,
    /// The admission queue is full; retry after `retry_after_ms`.
    Overloaded = 4,
    /// The batch panicked or an internal subsystem failed.
    Internal = 5,
    /// The model is quarantined after repeated failures; reload to clear.
    Quarantined = 6,
    /// The server is draining; no new predict work is admitted.
    ShuttingDown = 7,
}

impl ErrorCode {
    fn from_u8(v: u8) -> Option<ErrorCode> {
        Some(match v {
            1 => ErrorCode::BadRequest,
            2 => ErrorCode::UnknownModel,
            3 => ErrorCode::DeadlineExceeded,
            4 => ErrorCode::Overloaded,
            5 => ErrorCode::Internal,
            6 => ErrorCode::Quarantined,
            7 => ErrorCode::ShuttingDown,
            _ => return None,
        })
    }
}

/// A response frame.
#[derive(Debug, Clone)]
pub enum Response {
    /// Per-query nearest-medoid assignments and distances, request order.
    Assignments { id: u64, assign: Vec<u32>, dists: Vec<f64> },
    /// Typed failure; `retry_after_ms` is nonzero only for `Overloaded`.
    Error { id: u64, code: ErrorCode, retry_after_ms: u32, message: String },
    Pong { id: u64 },
    /// JSON snapshot of the server counters.
    Stats { id: u64, text: String },
    /// Human-readable reload report.
    ReloadAck { id: u64, text: String },
    ShutdownAck { id: u64 },
    /// Newline-separated `name kind k dim version` lines.
    ModelList { id: u64, text: String },
    /// Prometheus text exposition of the process metrics.
    Metrics { id: u64, text: String },
}

impl Response {
    /// The echoed request id.
    pub fn id(&self) -> u64 {
        match self {
            Response::Assignments { id, .. }
            | Response::Error { id, .. }
            | Response::Pong { id }
            | Response::Stats { id, .. }
            | Response::ReloadAck { id, .. }
            | Response::ShutdownAck { id }
            | Response::ModelList { id, .. }
            | Response::Metrics { id, .. } => *id,
        }
    }
}

/// Connection-fatal framing error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FrameError(pub String);

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for FrameError {}

/// Recoverable body-grammar failure: the connection survives, and the
/// error response echoes `id` (0 when the body was too short to carry
/// one).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseFailure {
    pub id: u64,
    pub message: String,
}

impl fmt::Display for ParseFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for ParseFailure {}

/// Bounds-checked little-endian body cursor (the model-reader pattern):
/// each read names its field, and lengths are verified against the bytes
/// present before anything is allocated.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Request id once parsed, echoed in failures.
    id: u64,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Cur<'a> {
        Cur { buf, pos: 0, id: 0 }
    }

    fn fail(&self, msg: impl fmt::Display) -> ParseFailure {
        ParseFailure { id: self.id, message: msg.to_string() }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], ParseFailure> {
        if self.remaining() < n {
            return Err(self.fail(format!(
                "truncated body: need {n} bytes for {what}, {} left",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, ParseFailure> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16, ParseFailure> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    fn u32(&mut self, what: &str) -> Result<u32, ParseFailure> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64, ParseFailure> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    /// The leading request id every body starts with.
    fn id_field(&mut self) -> Result<u64, ParseFailure> {
        let id = self.u64("request id")?;
        self.id = id;
        Ok(id)
    }

    /// `count` fixed-size scalars, length-checked before allocating.
    fn vec<T>(
        &mut self,
        count: usize,
        size: usize,
        what: &str,
        decode: impl Fn(&[u8]) -> T,
    ) -> Result<Vec<T>, ParseFailure> {
        let bytes = count
            .checked_mul(size)
            .ok_or_else(|| self.fail(format!("{what} count {count} overflows")))?;
        let raw = self.take(bytes, what)?;
        Ok(raw.chunks_exact(size).map(decode).collect())
    }

    /// Length-prefixed (u16) UTF-8 string, capped at `max`.
    fn short_string(&mut self, what: &str, max: usize) -> Result<String, ParseFailure> {
        let len = self.u16(what)? as usize;
        if len > max {
            return Err(self.fail(format!("{what} length {len} exceeds the cap {max}")));
        }
        let raw = self.take(len, what)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| self.fail(format!("{what} is not valid UTF-8")))
    }

    /// Length-prefixed (u32) UTF-8 text (response bodies).
    fn text(&mut self, what: &str) -> Result<String, ParseFailure> {
        let len = self.u32(what)? as usize;
        let raw = self.take(len, what)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| self.fail(format!("{what} is not valid UTF-8")))
    }

    fn finish(&self) -> Result<(), ParseFailure> {
        if self.remaining() != 0 {
            return Err(self.fail(format!(
                "{} trailing bytes after the body grammar",
                self.remaining()
            )));
        }
        Ok(())
    }
}

/// Read one frame header + body. `Ok(None)` on clean EOF at a frame
/// boundary; `Err` on anything that loses framing (bad magic/version,
/// oversized or truncated frame).
pub fn read_frame(r: &mut impl Read) -> Result<Option<(u8, Vec<u8>)>, FrameError> {
    let mut header = [0u8; 8];
    let mut got = 0;
    while got < header.len() {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(FrameError(format!(
                    "EOF inside a frame header ({got} of 8 bytes)"
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError(format!("reading frame header: {e}"))),
        }
    }
    if header[0..2] != MAGIC {
        return Err(FrameError(format!(
            "bad frame magic {:02x}{:02x} (expected \"BQ\")",
            header[0], header[1]
        )));
    }
    if header[2] != VERSION {
        return Err(FrameError(format!(
            "unsupported protocol version {} (expected {VERSION})",
            header[2]
        )));
    }
    let kind = header[3];
    let len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BODY {
        return Err(FrameError(format!(
            "frame body length {len} exceeds the cap {MAX_FRAME_BODY}"
        )));
    }
    let mut body = vec![0u8; len];
    let mut got = 0;
    while got < len {
        match r.read(&mut body[got..]) {
            Ok(0) => {
                return Err(FrameError(format!(
                    "EOF inside a frame body ({got} of {len} bytes)"
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError(format!("reading frame body: {e}"))),
        }
    }
    Ok(Some((kind, body)))
}

/// Write one frame (header + body).
pub fn write_frame(w: &mut impl Write, kind: u8, body: &[u8]) -> std::io::Result<()> {
    debug_assert!(body.len() <= MAX_FRAME_BODY);
    let mut header = [0u8; 8];
    header[0..2].copy_from_slice(&MAGIC);
    header[2] = VERSION;
    header[3] = kind;
    header[4..8].copy_from_slice(&(body.len() as u32).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(body)
}

/// Parse a request body for a frame `kind` read by [`read_frame`].
pub fn parse_request(kind: u8, body: &[u8]) -> Result<Request, ParseFailure> {
    let mut c = Cur::new(body);
    let id = c.id_field()?;
    let req = match kind {
        req::PREDICT => Request::Predict(parse_predict_body(&mut c, id)?),
        req::PING => Request::Ping { id },
        req::STATS => Request::Stats { id },
        req::RELOAD => {
            let name = c.short_string("model name", MAX_NAME)?;
            Request::Reload { id, name }
        }
        req::SHUTDOWN => Request::Shutdown { id },
        req::LIST_MODELS => Request::ListModels { id },
        req::METRICS => Request::Metrics { id },
        other => return Err(c.fail(format!("unknown request kind {other:#04x}"))),
    };
    c.finish()?;
    Ok(req)
}

fn parse_predict_body(c: &mut Cur<'_>, id: u64) -> Result<PredictRequest, ParseFailure> {
    let model = c.short_string("model name", MAX_NAME)?;
    if model.is_empty() {
        return Err(c.fail("model name must be nonempty"));
    }
    let deadline_ms = c.u32("deadline_ms")?;
    let storage = c.u8("storage tag")?;
    let n = c.u32("query count")? as usize;
    let dim = c.u32("query dim")? as usize;
    let queries = match storage {
        0 => {
            let count = n
                .checked_mul(dim)
                .ok_or_else(|| c.fail("n * dim overflows"))?;
            let values = c.vec(count, 4, "dense query payload", |b| {
                f32::from_le_bytes(b.try_into().unwrap())
            })?;
            if let Some(v) = values.iter().find(|v| !v.is_finite()) {
                return Err(c.fail(format!("non-finite query value {v}")));
            }
            Points::Dense(Matrix::from_vec(values, n, dim))
        }
        1 => {
            let nnz = usize::try_from(c.u64("nnz")?)
                .map_err(|_| c.fail("nnz exceeds the address space"))?;
            let indptr_raw = c.vec(
                n.checked_add(1).ok_or_else(|| c.fail("n overflows"))?,
                8,
                "indptr",
                |b| u64::from_le_bytes(b.try_into().unwrap()),
            )?;
            let mut indptr = Vec::with_capacity(indptr_raw.len());
            for p in indptr_raw {
                indptr.push(
                    usize::try_from(p).map_err(|_| c.fail("indptr entry overflows"))?,
                );
            }
            let indices = c.vec(nnz, 4, "column indices", |b| {
                u32::from_le_bytes(b.try_into().unwrap())
            })?;
            let values = c.vec(nnz, 4, "values", |b| {
                f32::from_le_bytes(b.try_into().unwrap())
            })?;
            // `try_from_parts` enforces every CSR invariant, including
            // finite nonzero values.
            let csr = CsrMatrix::try_from_parts(n, dim, indptr, indices, values)
                .map_err(|e| c.fail(format!("corrupt CSR query payload: {e}")))?;
            Points::Sparse(csr)
        }
        other => return Err(c.fail(format!("unknown storage tag {other}"))),
    };
    Ok(PredictRequest { id, model, deadline_ms, queries })
}

/// Encode a request as a complete frame (header + body). The inverse of
/// [`read_frame`] + [`parse_request`]; the golden fixtures pin both
/// directions byte-exactly.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut body = Vec::new();
    body.extend_from_slice(&req.id().to_le_bytes());
    let kind = match req {
        Request::Predict(p) => {
            debug_assert!(p.model.len() <= MAX_NAME);
            body.extend_from_slice(&(p.model.len() as u16).to_le_bytes());
            body.extend_from_slice(p.model.as_bytes());
            body.extend_from_slice(&p.deadline_ms.to_le_bytes());
            match &p.queries {
                Points::Dense(m) => {
                    body.push(0);
                    body.extend_from_slice(&(m.rows() as u32).to_le_bytes());
                    body.extend_from_slice(&(m.cols() as u32).to_le_bytes());
                    for &v in m.as_slice() {
                        body.extend_from_slice(&v.to_le_bytes());
                    }
                }
                Points::Sparse(m) => {
                    body.push(1);
                    body.extend_from_slice(&(m.rows() as u32).to_le_bytes());
                    body.extend_from_slice(&(m.cols() as u32).to_le_bytes());
                    let (indptr, indices, values) = m.parts();
                    body.extend_from_slice(&(indices.len() as u64).to_le_bytes());
                    for &p in indptr {
                        body.extend_from_slice(&(p as u64).to_le_bytes());
                    }
                    for &j in indices {
                        body.extend_from_slice(&j.to_le_bytes());
                    }
                    for &v in values {
                        body.extend_from_slice(&v.to_le_bytes());
                    }
                }
                Points::Trees(_) => {
                    unreachable!("tree queries have no wire form")
                }
            }
            req::PREDICT
        }
        Request::Ping { .. } => req::PING,
        Request::Stats { .. } => req::STATS,
        Request::Reload { name, .. } => {
            debug_assert!(name.len() <= MAX_NAME);
            body.extend_from_slice(&(name.len() as u16).to_le_bytes());
            body.extend_from_slice(name.as_bytes());
            req::RELOAD
        }
        Request::Shutdown { .. } => req::SHUTDOWN,
        Request::ListModels { .. } => req::LIST_MODELS,
        Request::Metrics { .. } => req::METRICS,
    };
    frame(kind, body)
}

/// Encode a response as a complete frame (header + body).
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut body = Vec::new();
    body.extend_from_slice(&resp.id().to_le_bytes());
    let kind = match resp {
        Response::Assignments { assign, dists, .. } => {
            debug_assert_eq!(assign.len(), dists.len());
            body.extend_from_slice(&(assign.len() as u32).to_le_bytes());
            for &a in assign {
                body.extend_from_slice(&a.to_le_bytes());
            }
            for &d in dists {
                body.extend_from_slice(&d.to_le_bytes());
            }
            resp::ASSIGNMENTS
        }
        Response::Error { code, retry_after_ms, message, .. } => {
            body.push(*code as u8);
            body.extend_from_slice(&retry_after_ms.to_le_bytes());
            let msg: String = message.chars().take(MAX_ERROR_MSG).collect();
            body.extend_from_slice(&(msg.len() as u16).to_le_bytes());
            body.extend_from_slice(msg.as_bytes());
            resp::ERROR
        }
        Response::Pong { .. } => resp::PONG,
        Response::Stats { text, .. } => {
            push_text(&mut body, text);
            resp::STATS
        }
        Response::ReloadAck { text, .. } => {
            push_text(&mut body, text);
            resp::RELOAD_ACK
        }
        Response::ShutdownAck { .. } => resp::SHUTDOWN_ACK,
        Response::ModelList { text, .. } => {
            push_text(&mut body, text);
            resp::MODEL_LIST
        }
        Response::Metrics { text, .. } => {
            push_text(&mut body, text);
            resp::METRICS
        }
    };
    frame(kind, body)
}

fn push_text(body: &mut Vec<u8>, text: &str) {
    body.extend_from_slice(&(text.len() as u32).to_le_bytes());
    body.extend_from_slice(text.as_bytes());
}

fn frame(kind: u8, body: Vec<u8>) -> Vec<u8> {
    assert!(body.len() <= MAX_FRAME_BODY, "frame body exceeds the cap");
    let mut out = Vec::with_capacity(8 + body.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(kind);
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Parse a response body (the client side of the protocol; the bench load
/// generator and the fault-injection tests decode through this). Same
/// hardening discipline as [`parse_request`].
pub fn parse_response(kind: u8, body: &[u8]) -> Result<Response, ParseFailure> {
    let mut c = Cur::new(body);
    let id = c.id_field()?;
    let resp = match kind {
        resp::ASSIGNMENTS => {
            let n = c.u32("assignment count")? as usize;
            let assign = c.vec(n, 4, "assignments", |b| {
                u32::from_le_bytes(b.try_into().unwrap())
            })?;
            let dists =
                c.vec(n, 8, "distances", |b| f64::from_le_bytes(b.try_into().unwrap()))?;
            Response::Assignments { id, assign, dists }
        }
        resp::ERROR => {
            let code = ErrorCode::from_u8(c.u8("error code")?)
                .ok_or_else(|| c.fail("unknown error code"))?;
            let retry_after_ms = c.u32("retry_after_ms")?;
            let message = c.short_string("error message", MAX_ERROR_MSG * 4)?;
            Response::Error { id, code, retry_after_ms, message }
        }
        resp::PONG => Response::Pong { id },
        resp::STATS => Response::Stats { id, text: c.text("stats text")? },
        resp::RELOAD_ACK => Response::ReloadAck { id, text: c.text("reload report")? },
        resp::SHUTDOWN_ACK => Response::ShutdownAck { id },
        resp::MODEL_LIST => Response::ModelList { id, text: c.text("model list")? },
        resp::METRICS => Response::Metrics { id, text: c.text("metrics text")? },
        other => return Err(c.fail(format!("unknown response kind {other:#04x}"))),
    };
    c.finish()?;
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_request(req: &Request) -> Request {
        let frame = encode_request(req);
        let mut r = &frame[..];
        let (kind, body) = read_frame(&mut r).unwrap().unwrap();
        assert!(read_frame(&mut r).unwrap().is_none(), "single frame");
        parse_request(kind, &body).unwrap()
    }

    fn roundtrip_response(resp: &Response) -> Response {
        let frame = encode_response(resp);
        let mut r = &frame[..];
        let (kind, body) = read_frame(&mut r).unwrap().unwrap();
        parse_response(kind, &body).unwrap()
    }

    #[test]
    fn control_requests_roundtrip() {
        for req in [
            Request::Ping { id: 1 },
            Request::Stats { id: 2 },
            Request::Reload { id: 3, name: "gmm".into() },
            Request::Reload { id: 4, name: String::new() },
            Request::Shutdown { id: 5 },
            Request::ListModels { id: 6 },
            Request::Metrics { id: 7 },
        ] {
            let back = roundtrip_request(&req);
            assert_eq!(back.id(), req.id());
            assert_eq!(
                std::mem::discriminant(&back),
                std::mem::discriminant(&req)
            );
            if let (Request::Reload { name: a, .. }, Request::Reload { name: b, .. }) =
                (&req, &back)
            {
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn dense_predict_roundtrips() {
        let req = Request::Predict(PredictRequest {
            id: 7,
            model: "gmm".into(),
            deadline_ms: 250,
            queries: Points::Dense(Matrix::from_vec(
                vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
                2,
                3,
            )),
        });
        let Request::Predict(back) = roundtrip_request(&req) else { unreachable!() };
        assert_eq!(back.id, 7);
        assert_eq!(back.model, "gmm");
        assert_eq!(back.deadline_ms, 250);
        let Points::Dense(m) = &back.queries else { unreachable!() };
        assert_eq!(m.as_slice(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn sparse_predict_roundtrips() {
        let csr = CsrMatrix::try_from_parts(
            2,
            4,
            vec![0, 2, 3],
            vec![0, 3, 1],
            vec![1.5, -2.0, 0.25],
        )
        .unwrap();
        let req = Request::Predict(PredictRequest {
            id: 42,
            model: "cells".into(),
            deadline_ms: 0,
            queries: Points::Sparse(csr.clone()),
        });
        let Request::Predict(back) = roundtrip_request(&req) else { unreachable!() };
        let Points::Sparse(m) = &back.queries else { unreachable!() };
        assert_eq!(m, &csr);
    }

    #[test]
    fn empty_dense_predict_roundtrips() {
        let req = Request::Predict(PredictRequest {
            id: 9,
            model: "gmm".into(),
            deadline_ms: 0,
            queries: Points::Dense(Matrix::zeros(0, 5)),
        });
        let Request::Predict(back) = roundtrip_request(&req) else { unreachable!() };
        assert_eq!(back.queries.len(), 0);
        assert_eq!(back.queries.dim(), Some(5));
    }

    #[test]
    fn responses_roundtrip() {
        let cases = [
            Response::Assignments { id: 1, assign: vec![0, 2, 1], dists: vec![0.5, 1.25, 0.0] },
            Response::Error {
                id: 2,
                code: ErrorCode::Overloaded,
                retry_after_ms: 50,
                message: "queue full".into(),
            },
            Response::Pong { id: 3 },
            Response::Stats { id: 4, text: "{\"admitted\":0}".into() },
            Response::ReloadAck { id: 5, text: "gmm: v2".into() },
            Response::ShutdownAck { id: 6 },
            Response::ModelList { id: 7, text: "gmm dense k=3 dim=8 v1".into() },
            Response::Metrics {
                id: 8,
                text: "# TYPE serve_queue_depth gauge\nserve_queue_depth 0\n".into(),
            },
        ];
        for resp in cases {
            let back = roundtrip_response(&resp);
            assert_eq!(back.id(), resp.id());
            match (&resp, &back) {
                (
                    Response::Assignments { assign: a1, dists: d1, .. },
                    Response::Assignments { assign: a2, dists: d2, .. },
                ) => {
                    assert_eq!(a1, a2);
                    let b1: Vec<u64> = d1.iter().map(|d| d.to_bits()).collect();
                    let b2: Vec<u64> = d2.iter().map(|d| d.to_bits()).collect();
                    assert_eq!(b1, b2);
                }
                (
                    Response::Error { code: c1, retry_after_ms: r1, message: m1, .. },
                    Response::Error { code: c2, retry_after_ms: r2, message: m2, .. },
                ) => {
                    assert_eq!(c1, c2);
                    assert_eq!(r1, r2);
                    assert_eq!(m1, m2);
                }
                (Response::Stats { text: t1, .. }, Response::Stats { text: t2, .. })
                | (
                    Response::ReloadAck { text: t1, .. },
                    Response::ReloadAck { text: t2, .. },
                )
                | (
                    Response::ModelList { text: t1, .. },
                    Response::ModelList { text: t2, .. },
                )
                | (
                    Response::Metrics { text: t1, .. },
                    Response::Metrics { text: t2, .. },
                ) => assert_eq!(t1, t2),
                (Response::Pong { .. }, Response::Pong { .. })
                | (Response::ShutdownAck { .. }, Response::ShutdownAck { .. }) => {}
                _ => panic!("variant changed in roundtrip"),
            }
        }
    }

    #[test]
    fn clean_eof_at_boundary_is_none() {
        let mut r: &[u8] = &[];
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn framing_violations_are_fatal_errors() {
        // bad magic
        let mut r: &[u8] = &[b'X', b'Q', 1, 2, 0, 0, 0, 0];
        assert!(read_frame(&mut r).unwrap_err().0.contains("magic"));
        // bad version
        let mut r: &[u8] = &[b'B', b'Q', 9, 2, 0, 0, 0, 0];
        assert!(read_frame(&mut r).unwrap_err().0.contains("version"));
        // oversized length, rejected before allocation
        let mut hdr = vec![b'B', b'Q', 1, 2];
        hdr.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut r: &[u8] = &hdr;
        assert!(read_frame(&mut r).unwrap_err().0.contains("exceeds"));
        // truncated header
        let mut r: &[u8] = &[b'B', b'Q', 1];
        assert!(read_frame(&mut r).unwrap_err().0.contains("header"));
        // truncated body
        let mut frame = vec![b'B', b'Q', 1, 2];
        frame.extend_from_slice(&16u32.to_le_bytes());
        frame.extend_from_slice(&[0u8; 4]);
        let mut r: &[u8] = &frame;
        assert!(read_frame(&mut r).unwrap_err().0.contains("body"));
    }

    #[test]
    fn error_message_is_truncated_on_encode() {
        let long = "x".repeat(MAX_ERROR_MSG * 3);
        let resp = Response::Error {
            id: 1,
            code: ErrorCode::Internal,
            retry_after_ms: 0,
            message: long,
        };
        let Response::Error { message, .. } = roundtrip_response(&resp) else {
            unreachable!()
        };
        assert_eq!(message.len(), MAX_ERROR_MSG);
    }

    #[test]
    fn predict_body_grammar_rejections_echo_the_id() {
        // valid frame, then corrupt the body in targeted ways
        let req = Request::Predict(PredictRequest {
            id: 0x0102_0304_0506_0708,
            model: "m".into(),
            deadline_ms: 0,
            queries: Points::Dense(Matrix::from_vec(vec![1.0, 2.0], 1, 2)),
        });
        let frame = encode_request(&req);
        let body = &frame[8..];
        // trailing bytes
        let mut long = body.to_vec();
        long.push(0);
        let err = parse_request(req::PREDICT, &long).unwrap_err();
        assert_eq!(err.id, 0x0102_0304_0506_0708);
        assert!(err.message.contains("trailing"));
        // truncated payload
        let err = parse_request(req::PREDICT, &body[..body.len() - 1]).unwrap_err();
        assert_eq!(err.id, 0x0102_0304_0506_0708);
        assert!(err.message.contains("truncated"));
        // too short to even carry an id
        let err = parse_request(req::PREDICT, &body[..4]).unwrap_err();
        assert_eq!(err.id, 0);
    }

    #[test]
    fn non_finite_dense_query_is_rejected() {
        let mut body = Vec::new();
        body.extend_from_slice(&1u64.to_le_bytes());
        body.extend_from_slice(&1u16.to_le_bytes());
        body.push(b'm');
        body.extend_from_slice(&0u32.to_le_bytes());
        body.push(0); // dense
        body.extend_from_slice(&1u32.to_le_bytes()); // n
        body.extend_from_slice(&2u32.to_le_bytes()); // dim
        body.extend_from_slice(&1.0f32.to_le_bytes());
        body.extend_from_slice(&f32::NAN.to_le_bytes());
        let err = parse_request(req::PREDICT, &body).unwrap_err();
        assert!(err.message.contains("non-finite"), "{}", err.message);
    }

    #[test]
    fn unknown_kinds_are_recoverable_rejections() {
        let mut body = Vec::new();
        body.extend_from_slice(&5u64.to_le_bytes());
        let err = parse_request(0x7f, &body).unwrap_err();
        assert_eq!(err.id, 5);
        assert!(err.message.contains("unknown request kind"));
        let err = parse_response(0x01, &body).unwrap_err();
        assert!(err.message.contains("unknown response kind"));
    }
}
