//! Long-lived prediction serving: load fitted `.bpmodel` files once and
//! answer assignment batches over a hardened binary protocol, on
//! stdin/stdout or a TCP socket (the `serve` subcommand).
//!
//! The subsystem is built for hostile clients and flaky models:
//!
//! * [`protocol`] — the length-prefixed wire format and its
//!   never-panics parser (every length checked before allocation).
//! * [`registry`] — named models with atomic hot swap (SIGHUP or a
//!   reload frame) and failure quarantine.
//! * [`batcher`] — bounded admission queue that coalesces small
//!   concurrent requests into one backend dispatch per model, sheds
//!   load with `Overloaded` + retry-after, and drains cleanly on
//!   shutdown.
//! * [`server`] — the connection/dispatcher machinery: per-request
//!   deadlines, `catch_unwind` panic isolation, warm predictor pool.
//! * [`faults`] — the deterministic fault-injection harness (forced
//!   panics, stalls, frame mutilators, slow-loris writer, in-memory
//!   pipe) behind the integration tests and `benches/serve.rs`.
//!
//! Wire-format and semantics reference: `rust/SERVE.md`.
//!
//! The serving contract: a healthy request's assignments are
//! bitwise-identical to a single-shot [`crate::model::KMedoidsModel::predict`]
//! against the same model generation, no matter how requests are
//! coalesced, how many threads the pool runs, or what faults hit the
//! neighboring traffic.

pub mod batcher;
pub mod faults;
pub mod protocol;
pub mod registry;
pub mod server;

pub use batcher::AdmissionConfig;
pub use registry::Registry;
pub use server::{install_sighup_handler, serve_tcp, ServeOptions, ServeStats, Server};
