//! Lock-free metrics primitives and the process-wide registry.
//!
//! Three metric kinds, all updatable from any thread without locking:
//!
//! * [`Counter`] — monotonically increasing `u64` (`_total` suffix by
//!   convention).
//! * [`Gauge`] — instantaneous `u64` value (queue depths, residency).
//! * [`Histogram`] — log2-bucketed value distribution with atomic
//!   buckets. Unlike [`crate::stats::Histogram`] (equal-width, built once
//!   from a finished sample), this one is fixed-bucket so concurrent
//!   `record` calls need no rebinning and two histograms merge by plain
//!   bucket-wise addition.
//!
//! The [`MetricsRegistry`] maps names to metrics. Registration takes a
//! mutex; the returned `Arc` handle is meant to be cached by the caller
//! (in a struct field or a `OnceLock`) so the hot path is a single
//! relaxed `fetch_add` — no locks, no allocation.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of log2 buckets: bucket 0 holds the value 0, bucket `i >= 1`
/// holds values in `[2^(i-1), 2^i)`, bucket 64 holds `>= 2^63`.
pub const HIST_BUCKETS: usize = 65;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// Fresh counter at zero.
    pub fn new() -> Counter {
        Counter::default()
    }

    /// Add 1.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Instantaneous value (may go up and down).
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    /// Fresh gauge at zero.
    pub fn new() -> Gauge {
        Gauge::default()
    }

    /// Overwrite the value.
    #[inline]
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Atomic log2-bucketed histogram of `u64` samples (latencies in
/// microseconds, sizes in nnz/bytes — any non-negative magnitude where
/// power-of-two resolution suffices).
pub struct Histogram {
    counts: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.snapshot();
        write!(f, "Histogram {{ count: {}, sum: {} }}", s.count, s.sum)
    }
}

impl Histogram {
    /// Fresh empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Bucket index of a value: 0 for 0, else `1 + floor(log2 v)`.
    #[inline]
    pub fn bucket_of(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    /// Inclusive upper edge of bucket `i` (the value reported for
    /// quantiles that land in the bucket).
    pub fn bucket_upper(i: usize) -> u64 {
        match i {
            0 => 0,
            1..=63 => (1u64 << i) - 1,
            _ => u64::MAX,
        }
    }

    /// Record one sample. Lock-free, allocation-free.
    #[inline]
    pub fn record(&self, v: u64) {
        self.counts[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a duration, in whole microseconds.
    #[inline]
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_micros().min(u64::MAX as u128) as u64);
    }

    /// Fold another histogram's samples into this one (bucket-wise sum).
    pub fn merge(&self, other: &Histogram) {
        for (dst, src) in self.counts.iter().zip(&other.counts) {
            dst.fetch_add(src.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Consistent point-in-time copy for quantile extraction.
    ///
    /// Buckets are read individually (no global lock), so a snapshot
    /// racing concurrent `record` calls may be mid-update; totals are
    /// re-derived from the bucket counts so the snapshot is always
    /// self-consistent.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let counts: [u64; HIST_BUCKETS] =
            std::array::from_fn(|i| self.counts[i].load(Ordering::Relaxed));
        HistogramSnapshot {
            count: counts.iter().sum(),
            sum: self.sum.load(Ordering::Relaxed),
            counts,
        }
    }
}

/// Non-atomic point-in-time copy of a [`Histogram`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub counts: [u64; HIST_BUCKETS],
    pub sum: u64,
    pub count: u64,
}

impl HistogramSnapshot {
    /// Upper edge of the bucket containing the `q`-quantile sample
    /// (`0.0 <= q <= 1.0`); 0 when empty. Log2 buckets bound the
    /// relative error at 2x — honest enough for p50/p99 reporting.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Histogram::bucket_upper(i);
            }
        }
        Histogram::bucket_upper(HIST_BUCKETS - 1)
    }

    /// Upper edge of the highest non-empty bucket (a 2x upper bound on
    /// the maximum recorded sample); 0 when empty.
    pub fn max_bound(&self) -> u64 {
        self.counts
            .iter()
            .rposition(|&c| c > 0)
            .map(Histogram::bucket_upper)
            .unwrap_or(0)
    }

    /// Mean of the recorded samples (exact — the sum is exact even
    /// though the buckets are coarse); 0.0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Bucket-wise difference vs an earlier snapshot of the same
    /// histogram — the samples recorded in between (benches use this to
    /// report per-scenario quantiles from cumulative process metrics).
    pub fn minus(&self, earlier: &HistogramSnapshot) -> HistogramSnapshot {
        let counts: [u64; HIST_BUCKETS] =
            std::array::from_fn(|i| self.counts[i].saturating_sub(earlier.counts[i]));
        HistogramSnapshot {
            count: counts.iter().sum(),
            sum: self.sum.saturating_sub(earlier.sum),
            counts,
        }
    }
}

/// One registered metric.
#[derive(Debug, Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn type_name(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

/// Split a registry name into `(family, labels)`: `kernel_us{kernel=
/// "l2_dense"}` -> `("kernel_us", Some("kernel=\"l2_dense\""))`; names
/// without a well-formed `{...}` suffix are a bare family.
fn split_labels(name: &str) -> (&str, Option<&str>) {
    match name.find('{') {
        Some(i) if name.ends_with('}') && name.len() > i + 2 => {
            (&name[..i], Some(&name[i + 1..name.len() - 1]))
        }
        _ => (name, None),
    }
}

/// Name → metric map. Get-or-register takes a mutex; cache the returned
/// handle for hot paths. Names follow Prometheus conventions:
/// `[a-z0-9_]+`, counters suffixed `_total`, unit suffixes `_us` / `_nnz`
/// spelled out (see `rust/OBS.md` for the full catalog).
pub struct MetricsRegistry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry::new()
    }
}

impl MetricsRegistry {
    /// Empty registry.
    pub const fn new() -> MetricsRegistry {
        MetricsRegistry { metrics: Mutex::new(BTreeMap::new()) }
    }

    /// Get or register the counter `name`.
    ///
    /// Panics if `name` is already registered as a different metric type
    /// (a naming bug worth failing loudly on).
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        let entry = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::new())));
        match entry {
            Metric::Counter(c) => Arc::clone(c),
            other => panic!("metric {name:?} already registered as {}", other.type_name()),
        }
    }

    /// Get or register the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        let entry = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::new())));
        match entry {
            Metric::Gauge(g) => Arc::clone(g),
            other => panic!("metric {name:?} already registered as {}", other.type_name()),
        }
    }

    /// Get or register the histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.metrics.lock().unwrap();
        let entry = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new())));
        match entry {
            Metric::Histogram(h) => Arc::clone(h),
            other => panic!("metric {name:?} already registered as {}", other.type_name()),
        }
    }

    /// Prometheus text exposition (`# TYPE` + samples, histograms as
    /// cumulative `_bucket{le=...}` series up to the highest non-empty
    /// bucket, then `+Inf`, `_sum`, `_count`). Deterministic order
    /// (sorted by name).
    ///
    /// A registry name may carry a label suffix — `kernel_us{kernel=
    /// "l2_dense"}` — in which case the family is the part before `{`:
    /// the `# TYPE` line is emitted once per family (labeled series of
    /// one family sort adjacently in the `BTreeMap`), samples keep the
    /// labels, and histogram buckets splice `le` after them.
    pub fn render_prometheus(&self) -> String {
        let metrics: Vec<(String, Metric)> = {
            let m = self.metrics.lock().unwrap();
            m.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
        };
        let mut out = String::new();
        let mut last_family: Option<String> = None;
        for (name, metric) in metrics {
            let (family, labels) = split_labels(&name);
            if last_family.as_deref() != Some(family) {
                let _ = writeln!(out, "# TYPE {family} {}", metric.type_name());
                last_family = Some(family.to_string());
            }
            // `{labels}` rendered back for plain samples, and as a prefix
            // (`label,`) ahead of `le` for bucket lines.
            let plain = match labels {
                Some(l) => format!("{{{l}}}"),
                None => String::new(),
            };
            let le_prefix = match labels {
                Some(l) => format!("{l},"),
                None => String::new(),
            };
            match metric {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "{family}{plain} {}", c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "{family}{plain} {}", g.get());
                }
                Metric::Histogram(h) => {
                    let s = h.snapshot();
                    let top = s.counts.iter().rposition(|&c| c > 0).unwrap_or(0);
                    let mut cum = 0u64;
                    for (i, &c) in s.counts.iter().enumerate().take(top + 1) {
                        cum += c;
                        let _ = writeln!(
                            out,
                            "{family}_bucket{{{le_prefix}le=\"{}\"}} {cum}",
                            Histogram::bucket_upper(i)
                        );
                    }
                    let _ =
                        writeln!(out, "{family}_bucket{{{le_prefix}le=\"+Inf\"}} {}", s.count);
                    let _ = writeln!(out, "{family}_sum{plain} {}", s.sum);
                    let _ = writeln!(out, "{family}_count{plain} {}", s.count);
                }
            }
        }
        out
    }

    /// JSON object snapshot (sorted keys): counters and gauges as
    /// numbers, histograms as `{count, sum, p50, p90, p99, max}` — the
    /// `metrics` envelope section of `BENCH_*.json`.
    pub fn snapshot_json(&self) -> String {
        let metrics: Vec<(String, Metric)> = {
            let m = self.metrics.lock().unwrap();
            m.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
        };
        let mut out = String::from("{");
        for (i, (name, metric)) in metrics.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            // Labeled names (`kernel_us{kernel="l2_dense"}`) carry quotes,
            // so the key must be escaped to stay valid JSON.
            let name = crate::util::json::escape(name);
            match metric {
                Metric::Counter(c) => {
                    let _ = write!(out, "\"{name}\": {}", c.get());
                }
                Metric::Gauge(g) => {
                    let _ = write!(out, "\"{name}\": {}", g.get());
                }
                Metric::Histogram(h) => {
                    let s = h.snapshot();
                    let _ = write!(
                        out,
                        "\"{name}\": {{\"count\": {}, \"sum\": {}, \"p50\": {}, \
                         \"p90\": {}, \"p99\": {}, \"max\": {}}}",
                        s.count,
                        s.sum,
                        s.quantile(0.50),
                        s.quantile(0.90),
                        s.quantile(0.99),
                        s.max_bound()
                    );
                }
            }
        }
        out.push('}');
        out
    }
}

/// The process-wide registry every subsystem records into.
pub fn global() -> &'static MetricsRegistry {
    static GLOBAL: MetricsRegistry = MetricsRegistry::new();
    &GLOBAL
}

/// Scoped timer recording its elapsed time (whole microseconds) into a
/// histogram on drop. Built on [`crate::util::timer::Timer`].
#[derive(Debug)]
pub struct Span<'a> {
    hist: &'a Histogram,
    timer: crate::util::timer::Timer,
}

impl<'a> Span<'a> {
    /// Start timing; records into `hist` when dropped.
    pub fn start(hist: &'a Histogram) -> Span<'a> {
        Span { hist, timer: crate::util::timer::Timer::start() }
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        self.hist.record_duration(self.timer.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
        let g = Gauge::new();
        g.set(7);
        assert_eq!(g.get(), 7);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn histogram_bucket_edges() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 1);
        assert_eq!(Histogram::bucket_of(2), 2);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(4), 3);
        assert_eq!(Histogram::bucket_of(u64::MAX), 64);
        assert_eq!(Histogram::bucket_upper(0), 0);
        assert_eq!(Histogram::bucket_upper(1), 1);
        assert_eq!(Histogram::bucket_upper(2), 3);
        assert_eq!(Histogram::bucket_upper(64), u64::MAX);
        // every value lands in a bucket whose upper edge bounds it
        for v in [0u64, 1, 2, 3, 5, 100, 1 << 40, u64::MAX] {
            assert!(v <= Histogram::bucket_upper(Histogram::bucket_of(v)), "{v}");
        }
    }

    #[test]
    fn histogram_quantiles_and_mean() {
        let h = Histogram::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.sum, 5050);
        assert!((s.mean() - 50.5).abs() < 1e-9);
        // p50 of 1..=100 is the 50th sample (value 50), bucket upper 63
        assert_eq!(s.quantile(0.5), 63);
        // p100 is value 100, bucket [64,128) upper 127
        assert_eq!(s.quantile(1.0), 127);
        assert_eq!(s.max_bound(), 127);
        // empty histogram
        assert_eq!(Histogram::new().snapshot().quantile(0.5), 0);
    }

    #[test]
    fn histogram_merge_equals_combined_recording() {
        let a = Histogram::new();
        let b = Histogram::new();
        let both = Histogram::new();
        for v in 0..50u64 {
            a.record(v * 3);
            both.record(v * 3);
        }
        for v in 0..70u64 {
            b.record(v * 7 + 1);
            both.record(v * 7 + 1);
        }
        a.merge(&b);
        assert_eq!(a.snapshot(), both.snapshot());
    }

    #[test]
    fn snapshot_minus_recovers_the_delta() {
        let h = Histogram::new();
        h.record(10);
        h.record(20);
        let before = h.snapshot();
        h.record(1000);
        h.record(2000);
        let delta = h.snapshot().minus(&before);
        assert_eq!(delta.count, 2);
        assert_eq!(delta.sum, 3000);
        assert_eq!(delta.quantile(1.0), 2047);
    }

    #[test]
    fn registry_returns_same_handle_and_renders_both_formats() {
        let r = MetricsRegistry::new();
        let c = r.counter("test_requests_total");
        r.counter("test_requests_total").add(2);
        c.inc();
        assert_eq!(c.get(), 3);
        r.gauge("test_depth").set(4);
        r.histogram("test_latency_us").record(100);

        let prom = r.render_prometheus();
        assert!(prom.contains("# TYPE test_requests_total counter"), "{prom}");
        assert!(prom.contains("test_requests_total 3"), "{prom}");
        assert!(prom.contains("# TYPE test_depth gauge"), "{prom}");
        assert!(prom.contains("test_depth 4"), "{prom}");
        assert!(prom.contains("# TYPE test_latency_us histogram"), "{prom}");
        assert!(prom.contains("test_latency_us_bucket{le=\"+Inf\"} 1"), "{prom}");
        assert!(prom.contains("test_latency_us_sum 100"), "{prom}");

        let json = crate::util::json::Json::parse(&r.snapshot_json()).expect("valid json");
        assert_eq!(json.get("test_requests_total"), Some(&crate::util::json::Json::Num(3.0)));
        assert!(json.get("test_latency_us").and_then(|h| h.get("p50")).is_some());
    }

    #[test]
    fn labeled_series_share_one_type_line_and_splice_le() {
        let r = MetricsRegistry::new();
        r.histogram("test_kernel_us{kernel=\"cosine_dense\"}").record(5);
        r.histogram("test_kernel_us{kernel=\"l2_dense\"}").record(9);
        r.counter("test_tiles_total{kind=\"sparse\"}").add(2);
        let prom = r.render_prometheus();
        // One TYPE line per family, even with two labeled series.
        assert_eq!(prom.matches("# TYPE test_kernel_us histogram").count(), 1, "{prom}");
        // value 5 lands in the (3, 7] bucket; value 9 in (7, 15].
        assert!(
            prom.contains("test_kernel_us_bucket{kernel=\"cosine_dense\",le=\"7\"} 1"),
            "{prom}"
        );
        assert!(prom.contains("test_kernel_us_bucket{kernel=\"l2_dense\",le=\"15\"} 1"), "{prom}");
        assert!(
            prom.contains("test_kernel_us_bucket{kernel=\"l2_dense\",le=\"+Inf\"} 1"),
            "{prom}"
        );
        assert!(prom.contains("test_kernel_us_sum{kernel=\"l2_dense\"} 9"), "{prom}");
        assert!(prom.contains("test_kernel_us_count{kernel=\"cosine_dense\"} 1"), "{prom}");
        assert!(prom.contains("# TYPE test_tiles_total counter"), "{prom}");
        assert!(prom.contains("test_tiles_total{kind=\"sparse\"} 2"), "{prom}");
        // No bare-name samples leak for labeled series.
        assert!(!prom.contains("test_kernel_us_sum "), "{prom}");
    }

    #[test]
    fn split_labels_handles_plain_and_malformed_names() {
        assert_eq!(split_labels("plain_total"), ("plain_total", None));
        assert_eq!(
            split_labels("kernel_us{kernel=\"l1_sparse\"}"),
            ("kernel_us", Some("kernel=\"l1_sparse\""))
        );
        // Malformed suffixes degrade to a bare family, never panic.
        assert_eq!(split_labels("odd{"), ("odd{", None));
        assert_eq!(split_labels("odd{}"), ("odd{}", None));
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn registry_rejects_type_confusion() {
        let r = MetricsRegistry::new();
        r.counter("test_x");
        r.gauge("test_x");
    }

    #[test]
    fn span_records_on_drop() {
        let h = Histogram::new();
        {
            let _s = Span::start(&h);
        }
        assert_eq!(h.snapshot().count, 1);
    }
}
