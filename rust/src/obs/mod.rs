//! Crate-wide observability: metrics registry, phase-span tracing, and
//! telemetry export.
//!
//! Three layers (see `rust/OBS.md` for the operator-facing catalog):
//!
//! 1. **Metrics core** ([`metrics`]) — a process-wide
//!    [`MetricsRegistry`] of named atomic [`Counter`]s, [`Gauge`]s and
//!    log2-bucketed [`Histogram`]s. Updates are lock-free; registration
//!    hands out `Arc` handles meant to be cached by the instrumented
//!    subsystem, so kernel paths pay one relaxed `fetch_add` and zero
//!    allocations.
//! 2. **Structured trace** ([`trace`]) — an opt-in JSONL event writer
//!    ([`TraceSink`]) emitting phase spans from the coordinator (per
//!    BUILD round / SWAP iteration) and from BigFit/stream (per sample /
//!    window). Disabled (`None`) everywhere by default; enabling it
//!    never changes a fit's results (bitwise-inert, pinned by
//!    `tests/property_obs.rs`).
//! 3. **Export surfaces** — Prometheus text exposition
//!    ([`MetricsRegistry::render_prometheus`], reachable through the
//!    `serve` protocol's `metrics` frame and the `--metrics-dump` CLI
//!    flag) and the JSON snapshot embedded in every `BENCH_*.json`
//!    envelope ([`crate::bench::report`]).

pub mod metrics;
pub mod trace;

pub use metrics::{global, Counter, Gauge, Histogram, HistogramSnapshot, MetricsRegistry, Span};
pub use trace::{SharedBuf, TraceSink, TraceValue};
