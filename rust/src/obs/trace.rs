//! Opt-in JSONL trace sink for phase spans.
//!
//! A [`TraceSink`] serializes structured events — one JSON object per
//! line — with a process-ordered `seq` number, so consumers can replay
//! the exact emission order without trusting wall clocks. Producers hold
//! an `Option<Arc<TraceSink>>`: when it is `None` (the default
//! everywhere), tracing code is a branch on a `None` and nothing else —
//! no allocation, no formatting, no lock. Emission only *reads* fit
//! state (counters, outcomes), never participates in it, so traced and
//! untraced fits are bitwise-identical (`tests/property_obs.rs`).
//!
//! Event catalog and field schema: `rust/OBS.md`.

use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::util::json::escape;

/// One field value in a trace event.
#[derive(Debug, Clone)]
pub enum TraceValue {
    U64(u64),
    I64(i64),
    F64(f64),
    Bool(bool),
    Str(String),
}

impl TraceValue {
    fn write_json(&self, out: &mut String) {
        use std::fmt::Write as _;
        match self {
            TraceValue::U64(v) => {
                let _ = write!(out, "{v}");
            }
            TraceValue::I64(v) => {
                let _ = write!(out, "{v}");
            }
            TraceValue::F64(v) if v.is_finite() => {
                let _ = write!(out, "{v}");
            }
            TraceValue::F64(_) => out.push_str("null"),
            TraceValue::Bool(v) => {
                let _ = write!(out, "{v}");
            }
            TraceValue::Str(s) => {
                out.push('"');
                out.push_str(&escape(s));
                out.push('"');
            }
        }
    }
}

impl From<u64> for TraceValue {
    fn from(v: u64) -> Self {
        TraceValue::U64(v)
    }
}

impl From<usize> for TraceValue {
    fn from(v: usize) -> Self {
        TraceValue::U64(v as u64)
    }
}

impl From<i64> for TraceValue {
    fn from(v: i64) -> Self {
        TraceValue::I64(v)
    }
}

impl From<f64> for TraceValue {
    fn from(v: f64) -> Self {
        TraceValue::F64(v)
    }
}

impl From<bool> for TraceValue {
    fn from(v: bool) -> Self {
        TraceValue::Bool(v)
    }
}

impl From<&str> for TraceValue {
    fn from(v: &str) -> Self {
        TraceValue::Str(v.to_string())
    }
}

impl From<String> for TraceValue {
    fn from(v: String) -> Self {
        TraceValue::Str(v)
    }
}

/// JSONL event writer with process-ordered sequence numbers.
pub struct TraceSink {
    out: Mutex<Box<dyn Write + Send>>,
    seq: AtomicU64,
}

impl std::fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TraceSink {{ seq: {} }}", self.seq.load(Ordering::Relaxed))
    }
}

impl TraceSink {
    /// Sink writing to a file at `path` (buffered; created/truncated).
    pub fn to_path(path: impl AsRef<Path>) -> Result<Arc<TraceSink>> {
        let path = path.as_ref();
        let file = std::fs::File::create(path).map_err(|e| {
            Error::data(format!("cannot create trace file {}: {e}", path.display()))
        })?;
        Ok(Arc::new(Self::to_writer(Box::new(BufWriter::new(file)))))
    }

    /// Sink writing to an arbitrary writer (tests use an in-memory
    /// buffer).
    pub fn to_writer(out: Box<dyn Write + Send>) -> TraceSink {
        TraceSink { out: Mutex::new(out), seq: AtomicU64::new(0) }
    }

    /// Events emitted so far.
    pub fn len(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// No events emitted yet?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Emit one event line: `{"seq": N, "event": "<event>", <fields...>}`.
    ///
    /// `seq` is claimed under the writer lock, so sequence numbers are
    /// dense and strictly increasing in file order even under concurrent
    /// emitters. Write errors are swallowed (telemetry must never fail a
    /// fit); callers that care should `flush()` and check.
    pub fn emit(&self, event: &str, fields: &[(&str, TraceValue)]) {
        let mut line = String::with_capacity(64 + fields.len() * 24);
        line.push_str("{\"seq\": ");
        let mut out = self.out.lock().unwrap();
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        use std::fmt::Write as _;
        let _ = write!(line, "{seq}, \"event\": \"{}\"", escape(event));
        for (k, v) in fields {
            let _ = write!(line, ", \"{}\": ", escape(k));
            v.write_json(&mut line);
        }
        line.push_str("}\n");
        let _ = out.write_all(line.as_bytes());
    }

    /// Flush the underlying writer, reporting any I/O error.
    pub fn flush(&self) -> Result<()> {
        self.out
            .lock()
            .unwrap()
            .flush()
            .map_err(|e| Error::data(format!("flushing trace sink: {e}")))
    }
}

/// Shared in-memory buffer implementing `Write` — handed to
/// [`TraceSink::to_writer`] by tests (and anything else that wants to
/// inspect the emitted JSONL after the fact).
#[derive(Debug, Clone, Default)]
pub struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl SharedBuf {
    /// Fresh empty buffer.
    pub fn new() -> SharedBuf {
        SharedBuf::default()
    }

    /// Copy of the bytes written so far.
    pub fn contents(&self) -> Vec<u8> {
        self.0.lock().unwrap().clone()
    }

    /// The written bytes as UTF-8 text.
    pub fn text(&self) -> String {
        String::from_utf8(self.contents()).expect("trace output is UTF-8")
    }
}

impl Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn emits_well_formed_jsonl_with_dense_sequence() {
        let buf = SharedBuf::new();
        let sink = TraceSink::to_writer(Box::new(buf.clone()));
        sink.emit("alpha", &[("x", 1u64.into()), ("ok", true.into())]);
        sink.emit(
            "beta",
            &[
                ("ratio", 0.5f64.into()),
                ("label", "a \"quoted\" name".into()),
                ("bad", f64::NAN.into()),
            ],
        );
        sink.flush().unwrap();
        assert_eq!(sink.len(), 2);
        let text = buf.text();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for (i, line) in lines.iter().enumerate() {
            let v = Json::parse(line).expect("each line is valid JSON");
            assert_eq!(v.get("seq"), Some(&Json::Num(i as f64)), "line {i}");
        }
        let beta = Json::parse(lines[1]).unwrap();
        assert_eq!(beta.get("event"), Some(&Json::Str("beta".into())));
        assert_eq!(beta.get("ratio"), Some(&Json::Num(0.5)));
        assert_eq!(beta.get("label"), Some(&Json::Str("a \"quoted\" name".into())));
        assert_eq!(beta.get("bad"), Some(&Json::Null));
    }

    #[test]
    fn to_path_writes_and_flushes() {
        let p = std::env::temp_dir().join(format!("banditpam_trace_{}.jsonl", std::process::id()));
        let sink = TraceSink::to_path(&p).unwrap();
        sink.emit("ev", &[("n", 3usize.into())]);
        sink.flush().unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        assert!(body.contains("\"event\": \"ev\""), "{body}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn to_path_rejects_unwritable_location() {
        let err = TraceSink::to_path("/definitely/not/a/dir/trace.jsonl").unwrap_err();
        assert_eq!(err.kind(), "data");
    }
}
