//! Atomic distance-evaluation counter.
//!
//! The paper's primary efficiency metric (Figures 1b, Appendix Fig 5, the
//! "200x fewer distance computations" headline) is the number of distance
//! evaluations. Both backends increment one of these per evaluation; it is
//! atomic so the thread-sharded arm evaluation in the coordinator can share
//! it without locks.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Shared, thread-safe evaluation counter.
#[derive(Debug, Clone, Default)]
pub struct DistanceCounter {
    count: Arc<AtomicU64>,
}

impl DistanceCounter {
    /// New counter at zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `n` evaluations.
    #[inline]
    pub fn add(&self, n: u64) {
        self.count.fetch_add(n, Ordering::Relaxed);
    }

    /// Un-record `n` evaluations. Used by debug-only verification passes
    /// (`Clustering::finalize_with`) so debug and release builds report
    /// identical totals; not part of the measurement API.
    #[inline]
    pub(crate) fn sub(&self, n: u64) {
        self.count.fetch_sub(n, Ordering::Relaxed);
    }

    /// Current total.
    pub fn get(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Reset to zero (between experiment repetitions).
    pub fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_reset() {
        let c = DistanceCounter::new();
        c.add(5);
        c.add(7);
        assert_eq!(c.get(), 12);
        c.reset();
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn sub_reverses_add() {
        let c = DistanceCounter::new();
        c.add(10);
        c.sub(4);
        assert_eq!(c.get(), 6);
    }

    #[test]
    fn clones_share_state() {
        let c = DistanceCounter::new();
        let c2 = c.clone();
        c.add(3);
        c2.add(4);
        assert_eq!(c.get(), 7);
        assert_eq!(c2.get(), 7);
    }

    #[test]
    fn concurrent_increments() {
        let c = DistanceCounter::new();
        let pool = crate::runtime::pool::ThreadPool::new(8);
        pool.run(80_000, 1_000, &|start, end| {
            for _ in start..end {
                c.add(1);
            }
        });
        assert_eq!(c.get(), 80_000);
    }
}
