//! Sparse (CSR) distance kernels: merge pair kernels and scatter/gather
//! one-to-many row kernels.
//!
//! Every kernel is built from three *parts* per metric — a per-row
//! reduction table plus a cross term accumulated over stored entries:
//!
//! | metric | row table                 | cross term                       |
//! |--------|---------------------------|----------------------------------|
//! | l2     | `|x|^2` ([`sq_norm`])     | `x . y` ([`dot`])                |
//! | cosine | `|x|^2` ([`sq_norm`])     | `x . y` ([`dot`])                |
//! | l1     | `||x||_1` ([`abs_sum`])   | Σ over overlap of [`l1_term`]    |
//!
//! so a pair costs `O(nnz_a + nnz_b)` through the two-pointer merge, and
//! the hot one-to-many row path (see PR 1 / `rust/PERF.md` §7) costs
//! `O(nnz_b)` per reference: the target row is **scattered** once into a
//! dense scratch buffer and each reference streams its stored entries,
//! **gathering** target values by direct indexing.
//!
//! **Bitwise parity between the merge and scatter paths.** Both accumulate
//! sequentially in f64 over the reference row's stored entries in column
//! order. For a column the target does not store, the scratch holds
//! `0.0f32`, and both cross terms are *exactly* zero there (`v * 0.0` is a
//! signed zero; `l1_term(0, v) = (|0-v| - |0|) - |v| = 0.0`), and adding a
//! zero to a finite f64 accumulator does not change its bits. The merge
//! path simply skips those columns, so both paths produce bit-identical
//! sums — `NativeBackend::dist` (merge) and `NativeBackend::block`
//! (scatter) agree exactly, which the SWAP-reuse row cache and the
//! pairwise [`crate::distance::cache::DistanceCache`] rely on.
//!
//! Unlike the dense kernels (16-lane f32 accumulation), the sparse kernels
//! accumulate entirely in f64: stored runs are short (`nnz << d`), so lane
//! tricks buy little, and exact-zero semantics keep the scatter/merge
//! parity argument airtight. Sparse-vs-dense agreement is therefore only
//! within the *dense* kernels' f32 error (~1e-6 relative at d = 784), which
//! is what `tests/property_sparse.rs` asserts.

use crate::data::sparse::CsrMatrix;
use crate::distance::dense::cosine_from_parts;
use std::cell::RefCell;

/// `||x||_1` over stored values, sequential f64 (the l1 row table).
pub fn abs_sum(values: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for &v in values {
        s += (v as f64).abs();
    }
    s
}

/// `|x|^2` over stored values, sequential f64 (the l2/cosine row table).
pub fn sq_norm(values: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for &v in values {
        s += v as f64 * v as f64;
    }
    s
}

/// Sparse dot product via two-pointer merge over the column intersection,
/// accumulated sequentially in f64 in column order.
pub fn dot(ai: &[u32], av: &[f32], bi: &[u32], bv: &[f32]) -> f64 {
    debug_assert_eq!(ai.len(), av.len());
    debug_assert_eq!(bi.len(), bv.len());
    let (mut p, mut q) = (0usize, 0usize);
    let mut s = 0.0f64;
    while p < ai.len() && q < bi.len() {
        match ai[p].cmp(&bi[q]) {
            std::cmp::Ordering::Less => p += 1,
            std::cmp::Ordering::Greater => q += 1,
            std::cmp::Ordering::Equal => {
                s += av[p] as f64 * bv[q] as f64;
                p += 1;
                q += 1;
            }
        }
    }
    s
}

/// The l1 overlap correction for one shared column: what `|x - v|`
/// contributes *beyond* the `|x| + |v|` already counted by the two row
/// tables. Exactly `0.0` when either side is zero — the scatter path adds
/// it for every stored reference column and stays bit-identical to the
/// merge path, which only visits the intersection.
#[inline]
pub fn l1_term(x: f64, v: f64) -> f64 {
    ((x - v).abs() - x.abs()) - v.abs()
}

/// Σ [`l1_term`] over the column intersection (two-pointer merge,
/// sequential f64 in column order).
pub fn l1_corr(ai: &[u32], av: &[f32], bi: &[u32], bv: &[f32]) -> f64 {
    let (mut p, mut q) = (0usize, 0usize);
    let mut s = 0.0f64;
    while p < ai.len() && q < bi.len() {
        match ai[p].cmp(&bi[q]) {
            std::cmp::Ordering::Less => p += 1,
            std::cmp::Ordering::Greater => q += 1,
            std::cmp::Ordering::Equal => {
                s += l1_term(av[p] as f64, bv[q] as f64);
                p += 1;
                q += 1;
            }
        }
    }
    s
}

/// l1 distance from the parts: row tables plus overlap correction. The
/// clamp absorbs the last-ulp negative that rounding can produce for
/// near-identical rows.
#[inline]
pub fn l1_from_parts(abs_a: f64, abs_b: f64, corr: f64) -> f64 {
    ((abs_a + abs_b) + corr).max(0.0)
}

/// l2 distance from the parts: `sqrt(|a|^2 + |b|^2 - 2 a.b)`, clamped at
/// zero before the square root (cancellation for near-identical rows).
#[inline]
pub fn l2_from_parts(sq_a: f64, sq_b: f64, dot: f64) -> f64 {
    ((sq_a + sq_b) - 2.0 * dot).max(0.0).sqrt()
}

/// Pairwise sparse l1 (Manhattan) distance.
pub fn l1(ai: &[u32], av: &[f32], bi: &[u32], bv: &[f32]) -> f64 {
    l1_from_parts(abs_sum(av), abs_sum(bv), l1_corr(ai, av, bi, bv))
}

/// Pairwise sparse l2 (Euclidean) distance.
pub fn l2(ai: &[u32], av: &[f32], bi: &[u32], bv: &[f32]) -> f64 {
    l2_from_parts(sq_norm(av), sq_norm(bv), dot(ai, av, bi, bv))
}

/// Pairwise sparse cosine distance (zero rows get distance 1, matching
/// [`crate::distance::dense::cosine`]).
pub fn cosine(ai: &[u32], av: &[f32], bi: &[u32], bv: &[f32]) -> f64 {
    cosine_from_parts(dot(ai, av, bi, bv), sq_norm(av), sq_norm(bv))
}

thread_local! {
    /// Per-thread dense scratch for the scatter/gather row kernels. Kept
    /// all-zero between calls: [`with_scattered_row`] scatters the target's
    /// stored values in and un-scatters exactly those columns on the way
    /// out, so reuse never pays an O(d) clear.
    static SCATTER: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Run `body` with the sparse row `(ti, tv)` scattered into the
/// thread-local dense scratch buffer (length >= `cols`, zero everywhere
/// the row stores nothing). The row need not come from the same matrix as
/// the references streamed inside `body` — the query-vs-medoids cross
/// kernels scatter a *medoid* row and stream *query* rows, both over the
/// same `cols`-wide feature space.
fn with_scattered<R>(
    cols: usize,
    ti: &[u32],
    tv: &[f32],
    body: impl FnOnce(&[f32]) -> R,
) -> R {
    /// Un-scatters on drop, so the all-zero invariant survives a panic in
    /// `body`: pool workers outlive chunk panics, and a poisoned scratch
    /// would silently corrupt every later block on that thread.
    struct Unscatter<'a> {
        scratch: &'a mut Vec<f32>,
        cols: &'a [u32],
    }
    impl Drop for Unscatter<'_> {
        fn drop(&mut self) {
            for &j in self.cols {
                self.scratch[j as usize] = 0.0;
            }
        }
    }
    SCATTER.with(|cell| {
        let mut scratch = cell.borrow_mut();
        if scratch.len() < cols {
            scratch.resize(cols, 0.0);
        }
        for (&j, &v) in ti.iter().zip(tv) {
            scratch[j as usize] = v;
        }
        let guard = Unscatter { scratch: &mut *scratch, cols: ti };
        body(&*guard.scratch)
    })
}

/// [`with_scattered`] for row `t` of `m` (the same-matrix row kernels).
fn with_scattered_row<R>(m: &CsrMatrix, t: usize, body: impl FnOnce(&[f32]) -> R) -> R {
    let (ti, tv) = m.row(t);
    with_scattered(m.cols(), ti, tv, body)
}

/// One-to-many sparse l2 row kernel: `out[r] = l2(row t, row refs[r])`
/// against the precomputed squared-norm table (`sq_norms[i] = |row i|^2`,
/// as produced by [`sq_norm`]). `O(nnz_ref)` per reference via
/// scatter/gather; bit-identical to the pairwise [`l2`].
pub fn l2_row(m: &CsrMatrix, t: usize, sq_norms: &[f64], refs: &[usize], out: &mut [f64]) {
    l2_row_vs(m.row(t), sq_norms[t], m, sq_norms, refs, out)
}

/// Cross-matrix variant of [`l2_row`]: the target row `(ti, tv)` (with its
/// squared norm `sq_t`) may come from a *different* matrix than the
/// streamed references — the query-vs-medoids predict path scatters a
/// medoid row and streams query rows. Both sides must share the feature
/// space (`refs_m.cols()`). Accumulation order is identical to the
/// same-matrix kernel, so when `(ti, tv)` is a row of `refs_m` the two are
/// bit-for-bit equal.
pub fn l2_row_vs(
    t_row: (&[u32], &[f32]),
    sq_t: f64,
    refs_m: &CsrMatrix,
    ref_sq: &[f64],
    refs: &[usize],
    out: &mut [f64],
) {
    debug_assert_eq!(refs.len(), out.len());
    debug_assert!(t_row.0.last().is_none_or(|&j| (j as usize) < refs_m.cols()));
    with_scattered(refs_m.cols(), t_row.0, t_row.1, |scratch| {
        for (o, &r) in out.iter_mut().zip(refs) {
            let (ri, rv) = refs_m.row(r);
            let mut d = 0.0f64;
            for (&j, &v) in ri.iter().zip(rv) {
                d += v as f64 * scratch[j as usize] as f64;
            }
            *o = l2_from_parts(sq_t, ref_sq[r], d);
        }
    })
}

/// One-to-many sparse l1 row kernel against the precomputed abs-sum table
/// (`abs_sums[i] = ||row i||_1`, as produced by [`abs_sum`]).
/// Bit-identical to the pairwise [`l1`].
pub fn l1_row(m: &CsrMatrix, t: usize, abs_sums: &[f64], refs: &[usize], out: &mut [f64]) {
    l1_row_vs(m.row(t), abs_sums[t], m, abs_sums, refs, out)
}

/// Cross-matrix variant of [`l1_row`] (see [`l2_row_vs`] for the
/// target/reference split).
pub fn l1_row_vs(
    t_row: (&[u32], &[f32]),
    abs_t: f64,
    refs_m: &CsrMatrix,
    ref_abs: &[f64],
    refs: &[usize],
    out: &mut [f64],
) {
    debug_assert_eq!(refs.len(), out.len());
    debug_assert!(t_row.0.last().is_none_or(|&j| (j as usize) < refs_m.cols()));
    with_scattered(refs_m.cols(), t_row.0, t_row.1, |scratch| {
        for (o, &r) in out.iter_mut().zip(refs) {
            let (ri, rv) = refs_m.row(r);
            let mut corr = 0.0f64;
            for (&j, &v) in ri.iter().zip(rv) {
                corr += l1_term(scratch[j as usize] as f64, v as f64);
            }
            *o = l1_from_parts(abs_t, ref_abs[r], corr);
        }
    })
}

/// One-to-many sparse cosine row kernel against the precomputed
/// squared-norm table. Bit-identical to the pairwise [`cosine`].
pub fn cosine_row(m: &CsrMatrix, t: usize, sq_norms: &[f64], refs: &[usize], out: &mut [f64]) {
    cosine_row_vs(m.row(t), sq_norms[t], m, sq_norms, refs, out)
}

/// Cross-matrix variant of [`cosine_row`] (see [`l2_row_vs`] for the
/// target/reference split).
pub fn cosine_row_vs(
    t_row: (&[u32], &[f32]),
    sq_t: f64,
    refs_m: &CsrMatrix,
    ref_sq: &[f64],
    refs: &[usize],
    out: &mut [f64],
) {
    debug_assert_eq!(refs.len(), out.len());
    debug_assert!(t_row.0.last().is_none_or(|&j| (j as usize) < refs_m.cols()));
    with_scattered(refs_m.cols(), t_row.0, t_row.1, |scratch| {
        for (o, &r) in out.iter_mut().zip(refs) {
            let (ri, rv) = refs_m.row(r);
            let mut d = 0.0f64;
            for (&j, &v) in ri.iter().zip(rv) {
                d += v as f64 * scratch[j as usize] as f64;
            }
            *o = cosine_from_parts(d, sq_t, ref_sq[r]);
        }
    })
}

/// Per-row l1 table for a whole matrix (`abs_sums[i] = ||row i||_1`).
pub fn abs_sum_table(m: &CsrMatrix) -> Vec<f64> {
    (0..m.rows()).map(|i| abs_sum(m.row(i).1)).collect()
}

/// Per-row squared-norm table for a whole matrix (`sq_norms[i] = |row i|^2`).
pub fn sq_norm_table(m: &CsrMatrix) -> Vec<f64> {
    (0..m.rows()).map(|i| sq_norm(m.row(i).1)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::dense;
    use crate::util::matrix::Matrix;
    use crate::util::rng::Rng;

    /// Random sparse matrix with the requested density, via its dense twin
    /// (returned for reference comparisons).
    fn random_pair(rng: &mut Rng, n: usize, d: usize, density: f64) -> (CsrMatrix, Matrix) {
        let dense = Matrix::from_fn(n, d, |_, _| {
            if rng.bool(density) {
                let v = rng.normal() as f32;
                if v == 0.0 {
                    1.0
                } else {
                    v
                }
            } else {
                0.0
            }
        });
        (CsrMatrix::from_dense(&dense), dense)
    }

    #[test]
    fn merge_kernels_match_dense_kernels() {
        let mut rng = Rng::seed_from(51);
        for d in [1usize, 7, 31, 784] {
            let (sp, dn) = random_pair(&mut rng, 6, d, 0.3);
            for i in 0..6 {
                for j in 0..6 {
                    let (ai, av) = sp.row(i);
                    let (bi, bv) = sp.row(j);
                    let cases = [
                        (l1(ai, av, bi, bv), dense::l1(dn.row(i), dn.row(j)), "l1"),
                        (l2(ai, av, bi, bv), dense::l2(dn.row(i), dn.row(j)), "l2"),
                        (cosine(ai, av, bi, bv), dense::cosine(dn.row(i), dn.row(j)), "cos"),
                    ];
                    for (got, want, name) in cases {
                        let tol = 2e-5 * (1.0 + want.abs());
                        assert!((got - want).abs() <= tol, "{name} d={d} i={i} j={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn row_kernels_bitwise_equal_pairwise() {
        let mut rng = Rng::seed_from(52);
        for density in [0.05, 0.4] {
            let (sp, _) = random_pair(&mut rng, 10, 63, density);
            let refs: Vec<usize> = (0..10).collect();
            let mut out = vec![0.0f64; refs.len()];
            let abs = abs_sum_table(&sp);
            let sq = sq_norm_table(&sp);
            for t in 0..10 {
                let (ti, tv) = sp.row(t);
                l1_row(&sp, t, &abs, &refs, &mut out);
                for (&r, &o) in refs.iter().zip(&out) {
                    let (ri, rv) = sp.row(r);
                    assert_eq!(o, l1(ti, tv, ri, rv), "l1 t={t} r={r}");
                }
                l2_row(&sp, t, &sq, &refs, &mut out);
                for (&r, &o) in refs.iter().zip(&out) {
                    let (ri, rv) = sp.row(r);
                    assert_eq!(o, l2(ti, tv, ri, rv), "l2 t={t} r={r}");
                }
                cosine_row(&sp, t, &sq, &refs, &mut out);
                for (&r, &o) in refs.iter().zip(&out) {
                    let (ri, rv) = sp.row(r);
                    assert_eq!(o, cosine(ti, tv, ri, rv), "cos t={t} r={r}");
                }
            }
        }
    }

    /// The cross-matrix `_vs` kernels scatter a row from one matrix and
    /// stream references from another; against the merge pair kernels they
    /// must agree bit for bit (same exact-zero argument as the same-matrix
    /// path), which is what makes out-of-sample predict reproducible.
    #[test]
    fn cross_matrix_row_kernels_bitwise_equal_merge() {
        let mut rng = Rng::seed_from(54);
        let (targets, _) = random_pair(&mut rng, 5, 63, 0.3);
        let (queries, _) = random_pair(&mut rng, 9, 63, 0.15);
        let t_abs = abs_sum_table(&targets);
        let t_sq = sq_norm_table(&targets);
        let q_abs = abs_sum_table(&queries);
        let q_sq = sq_norm_table(&queries);
        let refs: Vec<usize> = (0..9).collect();
        let mut out = vec![0.0f64; refs.len()];
        for t in 0..5 {
            let (ti, tv) = targets.row(t);
            l1_row_vs((ti, tv), t_abs[t], &queries, &q_abs, &refs, &mut out);
            for (&r, &o) in refs.iter().zip(&out) {
                let (ri, rv) = queries.row(r);
                assert_eq!(o, l1(ti, tv, ri, rv), "l1 t={t} r={r}");
            }
            l2_row_vs((ti, tv), t_sq[t], &queries, &q_sq, &refs, &mut out);
            for (&r, &o) in refs.iter().zip(&out) {
                let (ri, rv) = queries.row(r);
                assert_eq!(o, l2(ti, tv, ri, rv), "l2 t={t} r={r}");
            }
            cosine_row_vs((ti, tv), t_sq[t], &queries, &q_sq, &refs, &mut out);
            for (&r, &o) in refs.iter().zip(&out) {
                let (ri, rv) = queries.row(r);
                assert_eq!(o, cosine(ti, tv, ri, rv), "cos t={t} r={r}");
            }
        }
    }

    #[test]
    fn scatter_scratch_resets_between_rows() {
        // Re-running with a different target must not see stale values:
        // give row 0 wide support and row 1 disjoint support.
        let m = CsrMatrix::from_triplets(
            3,
            5,
            &[(0, 0, 1.0), (0, 2, 2.0), (0, 4, 3.0), (1, 1, 4.0), (2, 2, 5.0)],
        );
        let abs = abs_sum_table(&m);
        let refs = [2usize];
        let mut out = [0.0f64];
        l1_row(&m, 0, &abs, &refs, &mut out);
        assert_eq!(out[0], (1.0 + 3.0) + 3.0); // |1|+|2-5|+|3|
        l1_row(&m, 1, &abs, &refs, &mut out);
        // target 1 shares no columns with ref 2: pure abs-sum distance
        assert_eq!(out[0], 4.0 + 5.0);
    }

    #[test]
    fn identical_rows_have_zero_distance() {
        let m = CsrMatrix::from_triplets(
            2,
            10,
            &[(0, 3, 1.5), (0, 7, -2.0), (1, 3, 1.5), (1, 7, -2.0)],
        );
        let (ai, av) = m.row(0);
        let (bi, bv) = m.row(1);
        assert_eq!(l1(ai, av, bi, bv), 0.0);
        assert_eq!(l2(ai, av, bi, bv), 0.0);
        assert!(cosine(ai, av, bi, bv).abs() < 1e-15);
    }

    #[test]
    fn zero_rows_match_dense_semantics() {
        let m = CsrMatrix::from_triplets(2, 4, &[(1, 0, 3.0), (1, 1, 4.0)]);
        let (ai, av) = m.row(0); // empty
        let (bi, bv) = m.row(1);
        assert_eq!(l1(ai, av, bi, bv), 7.0);
        assert_eq!(l2(ai, av, bi, bv), 5.0);
        assert_eq!(cosine(ai, av, bi, bv), 1.0); // zero vector convention
        assert_eq!(cosine(ai, av, ai, av), 1.0);
    }

    #[test]
    fn tables_match_scalar_reductions() {
        let mut rng = Rng::seed_from(53);
        let (sp, _) = random_pair(&mut rng, 8, 40, 0.25);
        let abs = abs_sum_table(&sp);
        let sq = sq_norm_table(&sp);
        for i in 0..8 {
            let (_, v) = sp.row(i);
            assert_eq!(abs[i], abs_sum(v));
            assert_eq!(sq[i], sq_norm(v));
        }
    }
}
