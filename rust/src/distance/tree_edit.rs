//! Zhang–Shasha tree edit distance [46].
//!
//! Exact ordered-tree edit distance with unit insert/delete/relabel costs —
//! the metric the paper uses on the HOC4 Code.org AST dataset (Figure 1b).
//! This is deliberately the *expensive* metric of the suite
//! (`O(|T1||T2| * min(depth, leaves)^2)`), which is exactly why counting
//! distance evaluations matters there.
//!
//! Implementation follows the classic formulation: postorder numbering,
//! leftmost-leaf-descendant array `l(i)`, LR-keyroots, and the forest
//! distance DP.

use crate::data::ast::Tree;

/// Flattened postorder view of a tree: interned labels + `l(i)` array.
struct PostOrder {
    labels: Vec<u32>,
    /// `lml[i]` = postorder index of the leftmost leaf descendant of node i.
    lml: Vec<usize>,
    keyroots: Vec<usize>,
}

impl PostOrder {
    fn build(t: &Tree) -> PostOrder {
        let mut labels = Vec::new();
        let mut lml = Vec::new();
        fn walk(t: &Tree, labels: &mut Vec<u32>, lml: &mut Vec<usize>) -> usize {
            // returns postorder index of the leftmost leaf under t
            let mut leftmost = usize::MAX;
            for (ci, c) in t.children.iter().enumerate() {
                let lm = walk(c, labels, lml);
                if ci == 0 {
                    leftmost = lm;
                }
            }
            let my_index = labels.len();
            if t.children.is_empty() {
                leftmost = my_index;
            }
            labels.push(t.label);
            lml.push(leftmost);
            leftmost
        }
        walk(t, &mut labels, &mut lml);
        // keyroots: nodes i such that no j > i has lml[j] == lml[i]
        let n = labels.len();
        let mut seen = std::collections::HashSet::new();
        let mut keyroots = Vec::new();
        for i in (0..n).rev() {
            if seen.insert(lml[i]) {
                keyroots.push(i);
            }
        }
        keyroots.sort_unstable();
        PostOrder { labels, lml, keyroots }
    }

    #[inline]
    fn len(&self) -> usize {
        self.labels.len()
    }
}

/// Unit-cost tree edit distance between two ASTs.
pub fn ted(a: &Tree, b: &Tree) -> f64 {
    let ta = PostOrder::build(a);
    let tb = PostOrder::build(b);
    let (na, nb) = (ta.len(), tb.len());
    let mut td = vec![0.0f64; na * nb]; // treedist[i][j]
    // forest-distance scratch, reused across keyroot pairs
    let mut fd = vec![0.0f64; (na + 1) * (nb + 1)];

    for &i in &ta.keyroots {
        for &j in &tb.keyroots {
            tree_dist(&ta, &tb, i, j, &mut td, &mut fd, nb);
        }
    }
    td[(na - 1) * nb + (nb - 1)]
}

#[inline]
fn cost_relabel(a: u32, b: u32) -> f64 {
    if a == b {
        0.0
    } else {
        1.0
    }
}

/// Fill `td[i][j]` for all pairs rooted in the keyroot subtrees (i, j).
#[allow(clippy::too_many_arguments)]
fn tree_dist(
    ta: &PostOrder,
    tb: &PostOrder,
    i: usize,
    j: usize,
    td: &mut [f64],
    fd: &mut [f64],
    nb: usize,
) {
    let li = ta.lml[i];
    let lj = tb.lml[j];
    let m = i - li + 2; // forest rows: li-1 .. i  (offset by li)
    let n = j - lj + 2;
    let stride = tb.len() + 1;
    // fd[(x)*stride + y] with x in [0, m), y in [0, n)
    fd[0] = 0.0;
    for x in 1..m {
        fd[x * stride] = fd[(x - 1) * stride] + 1.0; // delete
    }
    for y in 1..n {
        fd[y] = fd[y - 1] + 1.0; // insert
    }
    for x in 1..m {
        let ia = li + x - 1; // actual postorder index in ta
        for y in 1..n {
            let jb = lj + y - 1;
            if ta.lml[ia] == li && tb.lml[jb] == lj {
                // both forests are whole trees
                let d = (fd[(x - 1) * stride + y] + 1.0)
                    .min(fd[x * stride + y - 1] + 1.0)
                    .min(
                        fd[(x - 1) * stride + y - 1]
                            + cost_relabel(ta.labels[ia], tb.labels[jb]),
                    );
                fd[x * stride + y] = d;
                td[ia * nb + jb] = d;
            } else {
                let xa = ta.lml[ia].saturating_sub(li); // forest prefix length
                let yb = tb.lml[jb].saturating_sub(lj);
                let d = (fd[(x - 1) * stride + y] + 1.0)
                    .min(fd[x * stride + y - 1] + 1.0)
                    .min(fd[xa * stride + yb] + td[ia * nb + jb]);
                fd[x * stride + y] = d;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ast::Tree;

    fn leaf(l: u32) -> Tree {
        Tree { label: l, children: vec![] }
    }

    fn node(l: u32, ch: Vec<Tree>) -> Tree {
        Tree { label: l, children: ch }
    }

    #[test]
    fn identical_trees_zero() {
        let t = node(0, vec![leaf(1), node(2, vec![leaf(3)])]);
        assert_eq!(ted(&t, &t), 0.0);
    }

    #[test]
    fn single_relabel() {
        let a = node(0, vec![leaf(1)]);
        let b = node(0, vec![leaf(2)]);
        assert_eq!(ted(&a, &b), 1.0);
    }

    #[test]
    fn single_insert_delete() {
        let a = node(0, vec![leaf(1)]);
        let b = node(0, vec![leaf(1), leaf(2)]);
        assert_eq!(ted(&a, &b), 1.0);
        assert_eq!(ted(&b, &a), 1.0);
    }

    #[test]
    fn zhang_shasha_classic_example() {
        // The canonical example from the Zhang–Shasha paper:
        // T1 = f(d(a, c(b)), e),  T2 = f(c(d(a, b)), e): distance 2.
        let t1 = node(
            5, // f
            vec![node(3, vec![leaf(0), node(2, vec![leaf(1)])]), leaf(4)],
        );
        let t2 = node(
            5,
            vec![node(2, vec![node(3, vec![leaf(0), leaf(1)])]), leaf(4)],
        );
        assert_eq!(ted(&t1, &t2), 2.0);
    }

    #[test]
    fn distance_to_single_node_is_size_minus_overlap() {
        // Deleting everything but the root: |T| - 1 when labels match root.
        let t = node(0, vec![leaf(1), leaf(2), node(3, vec![leaf(4)])]);
        let single = leaf(0);
        assert_eq!(ted(&t, &single), 4.0);
    }

    #[test]
    fn symmetry_and_triangle_on_fixed_trees() {
        let a = node(0, vec![leaf(1), leaf(2)]);
        let b = node(0, vec![node(1, vec![leaf(2)])]);
        let c = node(3, vec![leaf(2)]);
        let dab = ted(&a, &b);
        let dba = ted(&b, &a);
        assert_eq!(dab, dba);
        let dac = ted(&a, &c);
        let dbc = ted(&b, &c);
        assert!(dac <= dab + dbc + 1e-12);
    }

    #[test]
    fn deep_chain_vs_wide_star() {
        // chain a-b-c-d vs star a(b,c,d): known small distance, must not
        // panic on degenerate shapes.
        let chain = node(0, vec![node(1, vec![node(2, vec![leaf(3)])])]);
        let star = node(0, vec![leaf(1), leaf(2), leaf(3)]);
        let d = ted(&chain, &star);
        assert!(d > 0.0 && d <= 4.0, "d = {d}");
    }
}
