//! Pairwise-distance cache (paper Appendix 2.2 "Intelligent Cache Design").
//!
//! The released BanditPAM implementation recomputes every distance; the
//! appendix observes that because each target needs only O(log n) reference
//! points *on average*, a cache of O(n log n) entries (rather than the full
//! n² matrix PAM/FastPAM1 precompute) captures most reuse — especially when
//! reference batches come from a **fixed permutation** so different arms
//! share reference points. The coordinator enables that mode via
//! [`crate::bandits::adaptive::SamplingMode::FixedPermutation`].
//!
//! Within the SWAP phase this pairwise cache is now largely subsumed by
//! the dense per-candidate row cache in
//! [`crate::coordinator::session::SwapSession`], which exploits the same
//! fixed ordering without per-probe locking; the hash cache remains the
//! general mechanism for BUILD, the baselines and arbitrary access
//! patterns, and composes with the session (a session fill that misses
//! here computes once and seeds both).
//!
//! Sharded `HashMap` protected by mutexes: the hot path takes one lock per
//! evaluation, but only on the (cheap) cache probe; misses compute outside
//! the lock.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

const SHARDS: usize = 64;

/// Thread-safe (i, j)-keyed distance cache with hit/miss statistics.
///
/// Keys are **point indices into the backend's fixed `Points`**, not row
/// storage: nothing here assumes dense rows, a row length, or any
/// particular feature representation, so the cache is correct verbatim
/// for `Points::Sparse` (CSR) — provided the engine's `dist` and `block`
/// paths return bit-identical values for a pair, which the sparse kernels
/// guarantee (see `distance/sparse.rs` §bitwise parity and the
/// `sparse_cache_path_matches_uncached_bitwise` / `tests/property_sparse`
/// coverage). A cache must never be shared across *different* `Points`
/// instances; `NativeBackend` owns one per backend, which enforces that.
pub struct DistanceCache {
    shards: Vec<Mutex<HashMap<u64, f64>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    capacity_per_shard: usize,
}

impl DistanceCache {
    /// Create with a total soft capacity (entries beyond it are not stored;
    /// the adaptive algorithm's access pattern is heavily skewed so simple
    /// insertion-capping behaves like LRU at a fraction of the cost).
    pub fn new(capacity: usize) -> Self {
        DistanceCache {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            capacity_per_shard: capacity / SHARDS + 1,
        }
    }

    /// Symmetric key: unordered pair (i, j).
    #[inline]
    fn key(i: usize, j: usize) -> u64 {
        let (a, b) = if i <= j { (i, j) } else { (j, i) };
        ((a as u64) << 32) | b as u64
    }

    /// Look up `d(i, j)`, computing and inserting via `f` on a miss.
    pub fn get_or_compute(&self, i: usize, j: usize, f: impl FnOnce() -> f64) -> f64 {
        let key = Self::key(i, j);
        let shard = &self.shards[(key % SHARDS as u64) as usize];
        if let Some(&d) = shard.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return d;
        }
        // compute outside the lock
        let d = f();
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut guard = shard.lock().unwrap();
        if guard.len() < self.capacity_per_shard {
            guard.insert(key, d);
        }
        d
    }

    /// (hits, misses) so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Cache hits so far (evaluations avoided).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses so far (evaluations actually computed).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Fraction of probes served from the cache (0 when never probed).
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = self.stats();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Number of stored entries.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// True when no entries are stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all entries and statistics.
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit() {
        let c = DistanceCache::new(1000);
        let mut calls = 0;
        let d1 = c.get_or_compute(1, 2, || {
            calls += 1;
            3.5
        });
        let d2 = c.get_or_compute(1, 2, || {
            calls += 1;
            999.0
        });
        assert_eq!(d1, 3.5);
        assert_eq!(d2, 3.5);
        assert_eq!(calls, 1);
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn symmetric_key() {
        let c = DistanceCache::new(1000);
        c.get_or_compute(7, 3, || 1.25);
        let d = c.get_or_compute(3, 7, || panic!("should be cached"));
        assert_eq!(d, 1.25);
    }

    #[test]
    fn capacity_cap_does_not_evict_but_stops_inserting() {
        let c = DistanceCache::new(SHARDS); // 2 per shard incl. +1
        for i in 0..10_000usize {
            c.get_or_compute(i, i + 1, || i as f64);
        }
        assert!(c.len() <= 2 * SHARDS);
        // values already stored remain correct
        let d = c.get_or_compute(0, 1, || panic!("evicted"));
        assert_eq!(d, 0.0);
    }

    #[test]
    fn hit_rate_tracks_probes() {
        let c = DistanceCache::new(1000);
        assert_eq!(c.hit_rate(), 0.0);
        c.get_or_compute(1, 2, || 1.0);
        c.get_or_compute(1, 2, || 1.0);
        c.get_or_compute(2, 1, || 1.0);
        assert_eq!(c.hits(), 2);
        assert_eq!(c.misses(), 1);
        assert!((c.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    /// Edge case pinned by ISSUE 4: before any probe, `hit_rate` must be
    /// exactly 0.0 — not NaN from a 0/0 — and the guard must also hold
    /// immediately after `clear()` resets both counters to zero.
    #[test]
    fn hit_rate_is_zero_not_nan_before_any_probe() {
        let c = DistanceCache::new(16);
        assert_eq!(c.stats(), (0, 0));
        let r = c.hit_rate();
        assert!(!r.is_nan(), "hit_rate must never be NaN");
        assert_eq!(r, 0.0);
        c.get_or_compute(3, 4, || 2.0);
        c.get_or_compute(3, 4, || 2.0);
        assert!(c.hit_rate() > 0.0);
        c.clear();
        assert_eq!(c.hit_rate(), 0.0);
        assert!(!c.hit_rate().is_nan());
    }

    #[test]
    fn clear_resets() {
        let c = DistanceCache::new(100);
        c.get_or_compute(0, 1, || 1.0);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.stats(), (0, 0));
    }

    #[test]
    fn concurrent_access_consistent() {
        // 4 pool lanes x 1000 probes each, through the persistent pool the
        // backend itself uses for sharded blocks.
        let c = DistanceCache::new(100_000);
        let pool = crate::runtime::pool::ThreadPool::new(4);
        pool.run(4000, 250, &|start, end| {
            for idx in start..end {
                let (t, i) = (idx / 1000, idx % 1000);
                let d = c.get_or_compute(i % 50, (i + t) % 50, || {
                    ((i % 50) * 100 + (i + t) % 50) as f64
                });
                assert!(d >= 0.0);
            }
        });
        assert!(c.len() <= 50 * 50);
    }
}
