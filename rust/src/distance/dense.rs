//! Optimized dense-vector distance kernels.
//!
//! This is the L3 hot path when the `NativeBackend` is active: a single
//! BanditPAM run at n = 10k touches these functions tens of millions of
//! times. The kernels accumulate in 16 independent f32 lanes (one AVX-512
//! register / two AVX2 registers after autovectorization with
//! `target-cpu=native`) and fold to f64 once at the end: 3.8x faster than
//! f64-lane accumulation, with worst-case relative error (d/16)*f32-eps
//! ~ 6e-6 at d = 784 — far below any clustering-relevant scale and applied
//! identically by every algorithm (see EXPERIMENTS.md §Perf).

/// Euclidean distance `||a - b||_2`.
#[inline]
pub fn l2(a: &[f32], b: &[f32]) -> f64 {
    sq_l2(a, b).sqrt()
}

/// Squared Euclidean distance (no sqrt; used by PCA and k-means-style code).
#[inline]
pub fn sq_l2(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n16 = a.len() - a.len() % 16;
    let mut acc = [0.0f32; 16];
    for (ca, cb) in a[..n16].chunks_exact(16).zip(b[..n16].chunks_exact(16)) {
        for l in 0..16 {
            let d = ca[l] - cb[l];
            acc[l] += d * d;
        }
    }
    let mut s = acc.iter().map(|&v| v as f64).sum::<f64>();
    for (x, y) in a[n16..].iter().zip(&b[n16..]) {
        let d = (*x - *y) as f64;
        s += d * d;
    }
    s
}

/// Manhattan distance `||a - b||_1` (same 16-lane f32 scheme as [`sq_l2`]).
#[inline]
pub fn l1(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n16 = a.len() - a.len() % 16;
    let mut acc = [0.0f32; 16];
    for (ca, cb) in a[..n16].chunks_exact(16).zip(b[..n16].chunks_exact(16)) {
        for l in 0..16 {
            acc[l] += (ca[l] - cb[l]).abs();
        }
    }
    let mut s = acc.iter().map(|&v| v as f64).sum::<f64>();
    for (x, y) in a[n16..].iter().zip(&b[n16..]) {
        s += ((*x - *y) as f64).abs();
    }
    s
}

/// Cosine distance `1 - a.b / (|a| |b|)`. Zero vectors get distance 1
/// (similarity 0), matching the Python oracle `ref.py`.
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n16 = a.len() - a.len() % 16;
    let mut dot = [0.0f32; 16];
    let mut na = [0.0f32; 16];
    let mut nb = [0.0f32; 16];
    for (x, y) in a[..n16].chunks_exact(16).zip(b[..n16].chunks_exact(16)) {
        for l in 0..16 {
            dot[l] += x[l] * y[l];
            na[l] += x[l] * x[l];
            nb[l] += y[l] * y[l];
        }
    }
    let mut d = dot.iter().map(|&v| v as f64).sum::<f64>();
    let mut sa = na.iter().map(|&v| v as f64).sum::<f64>();
    let mut sb = nb.iter().map(|&v| v as f64).sum::<f64>();
    for (x, y) in a[n16..].iter().zip(&b[n16..]) {
        let (xf, yf) = (*x as f64, *y as f64);
        d += xf * yf;
        sa += xf * xf;
        sb += yf * yf;
    }
    let denom = (sa * sb).sqrt();
    if denom > 0.0 {
        1.0 - d / denom
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_l2(a: &[f32], b: &[f32]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }

    fn naive_l1(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(x, y)| ((x - y) as f64).abs()).sum()
    }

    fn randvec(rng: &mut Rng, d: usize) -> Vec<f32> {
        (0..d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn pythagorean_triple() {
        assert!((l2(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((l1(&[0.0, 0.0], &[3.0, 4.0]) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn matches_naive_over_random_lengths() {
        let mut rng = Rng::seed_from(11);
        for d in [1, 3, 4, 7, 8, 31, 100, 784] {
            let a = randvec(&mut rng, d);
            let b = randvec(&mut rng, d);
            // blocked-f32 accumulation: relative error bounded by ~1e-5
            let t2 = 2e-5 * (1.0 + naive_l2(&a, &b));
            let t1 = 2e-5 * (1.0 + naive_l1(&a, &b));
            assert!((l2(&a, &b) - naive_l2(&a, &b)).abs() < t2, "d={d}");
            assert!((l1(&a, &b) - naive_l1(&a, &b)).abs() < t1, "d={d}");
        }
    }

    #[test]
    fn cosine_bounds_and_identity() {
        let mut rng = Rng::seed_from(12);
        for d in [2, 5, 64] {
            let a = randvec(&mut rng, d);
            let b = randvec(&mut rng, d);
            let c = cosine(&a, &b);
            assert!((0.0..=2.0 + 1e-12).contains(&c), "c={c}");
            assert!(cosine(&a, &a).abs() < 1e-12);
        }
    }

    #[test]
    fn cosine_opposite_vectors() {
        let a = [1.0f32, 0.0];
        let b = [-1.0f32, 0.0];
        assert!((cosine(&a, &b) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_zero_vector() {
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 1.0);
        assert_eq!(cosine(&[0.0, 0.0], &[0.0, 0.0]), 1.0);
    }

    #[test]
    fn symmetry() {
        let mut rng = Rng::seed_from(13);
        let a = randvec(&mut rng, 33);
        let b = randvec(&mut rng, 33);
        assert_eq!(l2(&a, &b), l2(&b, &a));
        assert_eq!(l1(&a, &b), l1(&b, &a));
        assert!((cosine(&a, &b) - cosine(&b, &a)).abs() < 1e-15);
    }

    #[test]
    fn empty_vectors() {
        assert_eq!(l2(&[], &[]), 0.0);
        assert_eq!(l1(&[], &[]), 0.0);
        assert_eq!(cosine(&[], &[]), 1.0);
    }
}
