//! Optimized dense-vector distance kernels.
//!
//! This is the L3 hot path when the `NativeBackend` is active: a single
//! BanditPAM run at n = 10k touches these functions tens of millions of
//! times. The kernels accumulate in 16 independent f32 lanes (one AVX-512
//! register / two AVX2 registers after autovectorization with
//! `target-cpu=native`) and fold to f64 once at the end: 3.8x faster than
//! f64-lane accumulation, with worst-case relative error (d/16)*f32-eps
//! ~ 6e-6 at d = 784 — far below any clustering-relevant scale and applied
//! identically by every algorithm (see EXPERIMENTS.md §Perf).
//!
//! Besides the pairwise kernels, this module provides **one-to-many row
//! kernels** ([`l2_row`], [`l1_row`], [`cosine_row`]) that evaluate
//! `d(target, refs[..])` in a single pass: the target row stays resident
//! while the references stream through, and the metric dispatch happens
//! once per row instead of once per pair. Cosine additionally accepts
//! **precomputed squared norms** ([`sq_norm`]) so each pair costs one dot
//! product instead of three reductions; the per-lane accumulation order is
//! identical to [`cosine`]'s internal norms, so the norm-table path is
//! bit-for-bit equal to the three-pass kernel. Architecture and measured
//! numbers: `rust/PERF.md`.

/// Euclidean distance `||a - b||_2`.
#[inline]
pub fn l2(a: &[f32], b: &[f32]) -> f64 {
    sq_l2(a, b).sqrt()
}

/// Squared Euclidean distance (no sqrt; used by PCA and k-means-style code).
#[inline]
pub fn sq_l2(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n16 = a.len() - a.len() % 16;
    let mut acc = [0.0f32; 16];
    for (ca, cb) in a[..n16].chunks_exact(16).zip(b[..n16].chunks_exact(16)) {
        for l in 0..16 {
            let d = ca[l] - cb[l];
            acc[l] += d * d;
        }
    }
    let mut s = acc.iter().map(|&v| v as f64).sum::<f64>();
    for (x, y) in a[n16..].iter().zip(&b[n16..]) {
        let d = (*x - *y) as f64;
        s += d * d;
    }
    s
}

/// Manhattan distance `||a - b||_1` (same 16-lane f32 scheme as [`sq_l2`]).
#[inline]
pub fn l1(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n16 = a.len() - a.len() % 16;
    let mut acc = [0.0f32; 16];
    for (ca, cb) in a[..n16].chunks_exact(16).zip(b[..n16].chunks_exact(16)) {
        for l in 0..16 {
            acc[l] += (ca[l] - cb[l]).abs();
        }
    }
    let mut s = acc.iter().map(|&v| v as f64).sum::<f64>();
    for (x, y) in a[n16..].iter().zip(&b[n16..]) {
        s += ((*x - *y) as f64).abs();
    }
    s
}

/// Cosine distance `1 - a.b / (|a| |b|)`. Zero vectors get distance 1
/// (similarity 0), matching the Python oracle `ref.py`.
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n16 = a.len() - a.len() % 16;
    let mut dot = [0.0f32; 16];
    let mut na = [0.0f32; 16];
    let mut nb = [0.0f32; 16];
    for (x, y) in a[..n16].chunks_exact(16).zip(b[..n16].chunks_exact(16)) {
        for l in 0..16 {
            dot[l] += x[l] * y[l];
            na[l] += x[l] * x[l];
            nb[l] += y[l] * y[l];
        }
    }
    let mut d = dot.iter().map(|&v| v as f64).sum::<f64>();
    let mut sa = na.iter().map(|&v| v as f64).sum::<f64>();
    let mut sb = nb.iter().map(|&v| v as f64).sum::<f64>();
    for (x, y) in a[n16..].iter().zip(&b[n16..]) {
        let (xf, yf) = (*x as f64, *y as f64);
        d += xf * yf;
        sa += xf * xf;
        sb += yf * yf;
    }
    let denom = (sa * sb).sqrt();
    if denom > 0.0 {
        1.0 - d / denom
    } else {
        1.0
    }
}

/// Dot product `a . b` (16-lane f32 accumulation, f64 fold — the same
/// scheme and per-lane operation order as the partial sums inside
/// [`cosine`], which makes [`cosine_from_parts`] bitwise-consistent).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n16 = a.len() - a.len() % 16;
    let mut acc = [0.0f32; 16];
    for (ca, cb) in a[..n16].chunks_exact(16).zip(b[..n16].chunks_exact(16)) {
        for l in 0..16 {
            acc[l] += ca[l] * cb[l];
        }
    }
    let mut s = acc.iter().map(|&v| v as f64).sum::<f64>();
    for (x, y) in a[n16..].iter().zip(&b[n16..]) {
        s += *x as f64 * *y as f64;
    }
    s
}

/// Squared L2 norm `|a|^2` for the cosine norm table (see `rust/PERF.md`).
#[inline]
pub fn sq_norm(a: &[f32]) -> f64 {
    dot(a, a)
}

/// Cosine distance from a precomputed dot product and squared norms.
/// Combines them exactly as [`cosine`] does (zero vectors get distance 1).
#[inline]
pub fn cosine_from_parts(dot: f64, sq_a: f64, sq_b: f64) -> f64 {
    let denom = (sq_a * sq_b).sqrt();
    if denom > 0.0 {
        1.0 - dot / denom
    } else {
        1.0
    }
}

/// One-to-many L2 row kernel: `out[r] = l2(a, refs[r])`.
///
/// `out.len()` must equal the number of reference rows yielded.
#[inline]
pub fn l2_row<'r>(a: &[f32], refs: impl Iterator<Item = &'r [f32]>, out: &mut [f64]) {
    let mut n = 0;
    for (o, b) in out.iter_mut().zip(refs) {
        *o = l2(a, b);
        n += 1;
    }
    debug_assert_eq!(n, out.len(), "row kernel output length mismatch");
}

/// One-to-many L1 row kernel: `out[r] = l1(a, refs[r])`.
#[inline]
pub fn l1_row<'r>(a: &[f32], refs: impl Iterator<Item = &'r [f32]>, out: &mut [f64]) {
    let mut n = 0;
    for (o, b) in out.iter_mut().zip(refs) {
        *o = l1(a, b);
        n += 1;
    }
    debug_assert_eq!(n, out.len(), "row kernel output length mismatch");
}

/// One-to-many cosine row kernel over a squared-norm table: each reference
/// arrives with its precomputed `|b|^2`, so the pair costs one [`dot`].
#[inline]
pub fn cosine_row<'r>(
    a: &[f32],
    sq_a: f64,
    refs: impl Iterator<Item = (&'r [f32], f64)>,
    out: &mut [f64],
) {
    let mut n = 0;
    for (o, (b, sq_b)) in out.iter_mut().zip(refs) {
        *o = cosine_from_parts(dot(a, b), sq_a, sq_b);
        n += 1;
    }
    debug_assert_eq!(n, out.len(), "row kernel output length mismatch");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_l2(a: &[f32], b: &[f32]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }

    fn naive_l1(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(x, y)| ((x - y) as f64).abs()).sum()
    }

    fn randvec(rng: &mut Rng, d: usize) -> Vec<f32> {
        (0..d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn pythagorean_triple() {
        assert!((l2(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((l1(&[0.0, 0.0], &[3.0, 4.0]) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn matches_naive_over_random_lengths() {
        let mut rng = Rng::seed_from(11);
        for d in [1, 3, 4, 7, 8, 31, 100, 784] {
            let a = randvec(&mut rng, d);
            let b = randvec(&mut rng, d);
            // blocked-f32 accumulation: relative error bounded by ~1e-5
            let t2 = 2e-5 * (1.0 + naive_l2(&a, &b));
            let t1 = 2e-5 * (1.0 + naive_l1(&a, &b));
            assert!((l2(&a, &b) - naive_l2(&a, &b)).abs() < t2, "d={d}");
            assert!((l1(&a, &b) - naive_l1(&a, &b)).abs() < t1, "d={d}");
        }
    }

    #[test]
    fn cosine_bounds_and_identity() {
        let mut rng = Rng::seed_from(12);
        for d in [2, 5, 64] {
            let a = randvec(&mut rng, d);
            let b = randvec(&mut rng, d);
            let c = cosine(&a, &b);
            assert!((0.0..=2.0 + 1e-12).contains(&c), "c={c}");
            assert!(cosine(&a, &a).abs() < 1e-12);
        }
    }

    #[test]
    fn cosine_opposite_vectors() {
        let a = [1.0f32, 0.0];
        let b = [-1.0f32, 0.0];
        assert!((cosine(&a, &b) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_zero_vector() {
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 1.0);
        assert_eq!(cosine(&[0.0, 0.0], &[0.0, 0.0]), 1.0);
    }

    #[test]
    fn symmetry() {
        let mut rng = Rng::seed_from(13);
        let a = randvec(&mut rng, 33);
        let b = randvec(&mut rng, 33);
        assert_eq!(l2(&a, &b), l2(&b, &a));
        assert_eq!(l1(&a, &b), l1(&b, &a));
        assert!((cosine(&a, &b) - cosine(&b, &a)).abs() < 1e-15);
    }

    #[test]
    fn empty_vectors() {
        assert_eq!(l2(&[], &[]), 0.0);
        assert_eq!(l1(&[], &[]), 0.0);
        assert_eq!(cosine(&[], &[]), 1.0);
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::seed_from(21);
        for d in [0, 1, 7, 16, 31, 100, 784] {
            let a = randvec(&mut rng, d);
            let b = randvec(&mut rng, d);
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            let tol = 2e-5 * (1.0 + naive.abs());
            assert!((dot(&a, &b) - naive).abs() < tol, "d={d}");
        }
    }

    #[test]
    fn cosine_from_parts_is_bitwise_equal_to_cosine() {
        let mut rng = Rng::seed_from(22);
        for d in [1, 2, 7, 16, 31, 100, 784] {
            let a = randvec(&mut rng, d);
            let b = randvec(&mut rng, d);
            let direct = cosine(&a, &b);
            let parts = cosine_from_parts(dot(&a, &b), sq_norm(&a), sq_norm(&b));
            assert_eq!(direct, parts, "d={d}");
        }
        // zero-vector semantics preserved
        assert_eq!(cosine_from_parts(0.0, 0.0, 5.0), 1.0);
    }

    #[test]
    fn row_kernels_match_pairwise_kernels() {
        let mut rng = Rng::seed_from(23);
        for d in [1, 7, 31, 784] {
            let a = randvec(&mut rng, d);
            let refs: Vec<Vec<f32>> = (0..9).map(|_| randvec(&mut rng, d)).collect();
            let mut out = vec![0.0; refs.len()];

            l2_row(&a, refs.iter().map(Vec::as_slice), &mut out);
            for (o, b) in out.iter().zip(&refs) {
                assert_eq!(*o, l2(&a, b), "l2 d={d}");
            }

            l1_row(&a, refs.iter().map(Vec::as_slice), &mut out);
            for (o, b) in out.iter().zip(&refs) {
                assert_eq!(*o, l1(&a, b), "l1 d={d}");
            }

            let sq_a = sq_norm(&a);
            cosine_row(
                &a,
                sq_a,
                refs.iter().map(|b| (b.as_slice(), sq_norm(b))),
                &mut out,
            );
            for (o, b) in out.iter().zip(&refs) {
                assert_eq!(*o, cosine(&a, b), "cosine d={d}");
            }
        }
    }
}
