//! Distance substrate: metrics, optimized dense kernels, tree edit
//! distance, evaluation counting and the optional pairwise cache.
//!
//! The paper's complexity results are stated in *number of distance
//! evaluations* — its own profiling shows >98% of wall-clock time is spent
//! here — so this module is both the hot path and the measurement point.
//! Every evaluation flows through a [`counter::DistanceCounter`] owned by
//! the active [`crate::runtime::backend::DistanceBackend`].

pub mod cache;
pub mod counter;
pub mod dense;
pub mod sparse;
pub mod tree_edit;

use crate::data::Points;

/// Supported (dis)similarity measures.
///
/// `d` need not be a metric (the k-medoids objective only needs a
/// dissimilarity); of these, all but `Cosine` are true metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Euclidean distance (MNIST experiments, Figs 1a/2).
    L2,
    /// Manhattan distance (scRNA experiments, Fig 3b; recommended in [37]).
    L1,
    /// Cosine distance `1 - cos(x, y)` (MNIST, Fig 3a).
    Cosine,
    /// Zhang–Shasha tree edit distance (HOC4 experiments, Fig 1b).
    TreeEdit,
}

impl Metric {
    /// Parse from the CLI spelling.
    pub fn parse(s: &str) -> Option<Metric> {
        match s.to_ascii_lowercase().as_str() {
            "l2" | "euclidean" => Some(Metric::L2),
            "l1" | "manhattan" => Some(Metric::L1),
            "cosine" | "cos" => Some(Metric::Cosine),
            "tree" | "tree_edit" | "ted" => Some(Metric::TreeEdit),
            _ => None,
        }
    }

    /// Canonical name (matches the Python artifact manifest spelling).
    pub fn name(&self) -> &'static str {
        match self {
            Metric::L2 => "l2",
            Metric::L1 => "l1",
            Metric::Cosine => "cosine",
            Metric::TreeEdit => "tree_edit",
        }
    }

    /// Is this metric applicable to the given point storage?
    pub fn supports(&self, points: &Points) -> bool {
        match (self, points) {
            (Metric::TreeEdit, Points::Trees(_)) => true,
            (Metric::TreeEdit, _) => false,
            (_, Points::Dense(_) | Points::Sparse(_)) => true,
            (_, Points::Trees(_)) => false,
        }
    }
}

impl std::fmt::Display for Metric {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Evaluate `d(points[i], points[j])` directly (uncounted).
///
/// Backends wrap this with counting; algorithm code should go through a
/// backend, not call this directly.
pub fn evaluate(metric: Metric, points: &Points, i: usize, j: usize) -> f64 {
    match (metric, points) {
        (Metric::L2, Points::Dense(m)) => dense::l2(m.row(i), m.row(j)),
        (Metric::L1, Points::Dense(m)) => dense::l1(m.row(i), m.row(j)),
        (Metric::Cosine, Points::Dense(m)) => dense::cosine(m.row(i), m.row(j)),
        (Metric::L2, Points::Sparse(m)) => {
            let ((ai, av), (bi, bv)) = (m.row(i), m.row(j));
            sparse::l2(ai, av, bi, bv)
        }
        (Metric::L1, Points::Sparse(m)) => {
            let ((ai, av), (bi, bv)) = (m.row(i), m.row(j));
            sparse::l1(ai, av, bi, bv)
        }
        (Metric::Cosine, Points::Sparse(m)) => {
            let ((ai, av), (bi, bv)) = (m.row(i), m.row(j));
            sparse::cosine(ai, av, bi, bv)
        }
        (Metric::TreeEdit, Points::Trees(ts)) => tree_edit::ted(&ts[i], &ts[j]),
        (m, p) => panic!("metric {m} not supported for {}", p.kind()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, Points};
    use crate::util::matrix::Matrix;

    #[test]
    fn parse_roundtrip() {
        for m in [Metric::L2, Metric::L1, Metric::Cosine, Metric::TreeEdit] {
            assert_eq!(Metric::parse(m.name()), Some(m));
        }
        assert_eq!(Metric::parse("chebyshev"), None);
    }

    #[test]
    fn supports_matrix_vs_trees() {
        let dense = Points::Dense(Matrix::zeros(2, 2));
        assert!(Metric::L2.supports(&dense));
        assert!(!Metric::TreeEdit.supports(&dense));
    }

    #[test]
    fn supports_and_evaluates_sparse() {
        let csr = crate::data::sparse::CsrMatrix::from_triplets(
            2,
            2,
            &[(1, 0, 3.0), (1, 1, 4.0)],
        );
        let pts = Points::Sparse(csr);
        for m in [Metric::L2, Metric::L1, Metric::Cosine] {
            assert!(m.supports(&pts), "{m}");
        }
        assert!(!Metric::TreeEdit.supports(&pts));
        assert_eq!(evaluate(Metric::L2, &pts, 0, 1), 5.0);
        assert_eq!(evaluate(Metric::L1, &pts, 0, 1), 7.0);
        assert_eq!(evaluate(Metric::Cosine, &pts, 0, 1), 1.0);
    }

    #[test]
    fn evaluate_dispatches() {
        let m = Matrix::from_vec(vec![0.0, 0.0, 3.0, 4.0], 2, 2);
        let pts = Points::Dense(m);
        assert!((evaluate(Metric::L2, &pts, 0, 1) - 5.0).abs() < 1e-6);
        assert!((evaluate(Metric::L1, &pts, 0, 1) - 7.0).abs() < 1e-6);
        let _ = Dataset::dense_from_points(pts); // smoke the helper
    }

    #[test]
    #[should_panic(expected = "not supported")]
    fn evaluate_wrong_combo_panics() {
        let pts = Points::Dense(Matrix::zeros(2, 2));
        evaluate(Metric::TreeEdit, &pts, 0, 1);
    }
}
