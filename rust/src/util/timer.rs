//! Wall-clock timing helpers shared by the bench harness and experiments.

use std::time::{Duration, Instant};

/// A simple start/stop stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed time since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as `f64`.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.secs())
}

/// Human-readable duration (e.g. `1.23s`, `45.6ms`, `789us`).
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.1}us", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_result_and_positive_time() {
        let (v, s) = timed(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499_500);
        assert!(s >= 0.0);
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(2.5), "2.50s");
        assert_eq!(fmt_duration(0.0123), "12.30ms");
        assert_eq!(fmt_duration(0.000123), "123.0us");
    }
}
