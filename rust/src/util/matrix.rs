//! Minimal dense row-major `f32` matrix used for point storage.
//!
//! Rows are points, columns are features. The distance kernels in
//! [`crate::distance::dense`] operate on `&[f32]` row slices of this type,
//! and [`crate::runtime::backend::XlaBackend`] gathers rows into padded PJRT
//! literals from it.

/// Dense row-major `f32` matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Build from a flat row-major buffer. Panics if sizes disagree.
    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Matrix { data, rows, cols }
    }

    /// Build from a closure `f(row, col) -> value`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { data, rows, cols }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i` as a feature slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row access.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Flat row-major view.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Column means (used by PCA centering).
    pub fn col_means(&self) -> Vec<f64> {
        let mut m = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            for (j, v) in self.row(i).iter().enumerate() {
                m[j] += *v as f64;
            }
        }
        let n = self.rows.max(1) as f64;
        m.iter_mut().for_each(|v| *v /= n);
        m
    }

    /// Select a subset of rows into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 4);
        assert_eq!(m.get(2, 3), 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
    }

    #[test]
    #[should_panic(expected = "buffer/shape mismatch")]
    fn bad_shape_panics() {
        Matrix::from_vec(vec![1.0; 5], 2, 3);
    }

    #[test]
    fn col_means() {
        let m = Matrix::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        assert_eq!(m.col_means(), vec![2.0, 3.0]);
    }

    #[test]
    fn select_rows_copies() {
        let m = Matrix::from_fn(4, 2, |i, _| i as f32);
        let s = m.select_rows(&[3, 1]);
        assert_eq!(s.row(0), &[3.0, 3.0]);
        assert_eq!(s.row(1), &[1.0, 1.0]);
    }

    #[test]
    fn row_mut_writes() {
        let mut m = Matrix::zeros(2, 2);
        m.row_mut(1).copy_from_slice(&[5.0, 6.0]);
        assert_eq!(m.get(1, 0), 5.0);
        assert_eq!(m.get(1, 1), 6.0);
    }
}
