//! Minimal JSON parser (the offline crate cache has no `serde`).
//!
//! Supports the full JSON grammar minus exotic number forms; used to read
//! `artifacts/manifest.json` and to emit experiment reports. Not
//! performance-critical.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array view.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// String view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Number as f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Number as usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, message: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(key, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Escape a string for embedding in JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "version": 1,
          "artifacts": [
            {"kind": "pairwise", "metric": "l2", "t": 64, "r": 128, "d": 784,
             "name": "pairwise_l2", "file": "pairwise_l2.hlo.txt"}
          ]
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("metric").unwrap().as_str(), Some("l2"));
        assert_eq!(arts[0].get("d").unwrap().as_usize(), Some(784));
    }

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[1, [2, 3], []]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[1].as_arr().unwrap()[1].as_f64(), Some(3.0));
        assert!(a[2].as_arr().unwrap().is_empty());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse("\"\\u00e9\"").unwrap();
        assert_eq!(j.as_str(), Some("é"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn escape_roundtrip() {
        let s = "line1\n\"quoted\"\tend\\";
        let doc = format!("\"{}\"", escape(s));
        assert_eq!(Json::parse(&doc).unwrap().as_str(), Some(s));
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("2.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-2").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }
}
