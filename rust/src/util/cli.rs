//! Hand-rolled CLI argument parsing (no `clap` in the offline cache).
//!
//! Supports the subset the `banditpam` binary needs:
//! `prog <subcommand> [--flag] [--key value] [--key=value] [positional...]`.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed command line: a subcommand, `--key value` options, bare `--flag`s
/// and positional arguments, in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Error produced when an option value fails to parse.
#[derive(Debug)]
pub struct ParseError {
    pub key: String,
    pub value: String,
    pub expected: &'static str,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "invalid value {:?} for --{} (expected {})",
            self.value, self.key, self.expected
        )
    }
}

impl std::error::Error for ParseError {}

/// Error produced when an option or flag is not in a subcommand's declared
/// accepted set — a misspelled `--sample_size` must fail loudly instead of
/// being silently ignored (the same failure class as the `.mtx` `--limit`
/// bug).
#[derive(Debug)]
pub struct UnknownOptionError {
    /// The subcommand whose table rejected the option.
    pub subcommand: String,
    /// The offending option/flag, without the leading `--`.
    pub option: String,
    /// Rendered list of what the subcommand does accept.
    pub accepted: String,
}

impl fmt::Display for UnknownOptionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown option --{} for `{}` (accepted: {})",
            self.option, self.subcommand, self.accepted
        )
    }
}

impl std::error::Error for UnknownOptionError {}

/// Options that never take a value (`--verbose file.csv` must not consume
/// `file.csv`). Everything else uses `--key value` / `--key=value`.
const BOOLEAN_FLAGS: &[&str] = &[
    "verbose", "csv", "force", "help", "quiet", "sparse", "stdio", "stream", "transpose",
];

/// On-disk dataset formats the `--data` loaders understand.
///
/// Spelled on the command line as `--format {csv,mtx,idx}`; when the flag
/// is absent, [`DataFormat::infer`] falls back to the file extension
/// (defaulting to CSV), so sparse Matrix Market datasets are selectable
/// from `main.rs` without code edits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataFormat {
    /// Headerless dense CSV (rows = points).
    Csv,
    /// Matrix Market coordinate triplets (sparse; 10x Genomics style).
    Mtx,
    /// MNIST IDX3 images.
    Idx,
}

impl DataFormat {
    /// Parse the CLI spelling.
    pub fn parse(s: &str) -> Option<DataFormat> {
        match s.to_ascii_lowercase().as_str() {
            "csv" => Some(DataFormat::Csv),
            "mtx" | "matrixmarket" | "matrix-market" => Some(DataFormat::Mtx),
            "idx" | "idx3" | "mnist" => Some(DataFormat::Idx),
            _ => None,
        }
    }

    /// Infer from a path's extension; CSV when unrecognized (the
    /// historical default).
    pub fn infer(path: &str) -> DataFormat {
        let ext = path.rsplit('.').next().unwrap_or("");
        DataFormat::parse(ext).unwrap_or(DataFormat::Csv)
    }

    /// Canonical CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            DataFormat::Csv => "csv",
            DataFormat::Mtx => "mtx",
            DataFormat::Idx => "idx",
        }
    }
}

impl fmt::Display for DataFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl Args {
    /// Parse from an iterator of argument strings (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if !BOOLEAN_FLAGS.contains(&stripped)
                    && it
                        .peek()
                        .map(|nxt| !nxt.starts_with("--"))
                        .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Is the bare flag present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw option value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option value parsed as `T`, with a default.
    pub fn get_parsed<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
    ) -> Result<T, ParseError> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| ParseError {
                key: key.to_string(),
                value: v.clone(),
                expected: std::any::type_name::<T>(),
            }),
        }
    }

    /// Validate every parsed `--key value` option and bare `--flag`
    /// against a subcommand's declared accepted sets. The parser accepts
    /// anything shaped like an option, so without this check a misspelled
    /// key (`--sample_size` for `--sample-size`) lands in the option map
    /// and is silently never read; the first unknown option wins and
    /// surfaces as a usage error (exit 2 via `Error::InvalidArgument`).
    pub fn check_known(
        &self,
        subcommand: &str,
        keys: &[&str],
        flags: &[&str],
    ) -> Result<(), UnknownOptionError> {
        let accepted = || {
            let mut all: Vec<String> = keys.iter().map(|k| format!("--{k} V")).collect();
            all.extend(flags.iter().map(|f| format!("--{f}")));
            all.sort();
            all.join(", ")
        };
        for key in self.options.keys() {
            if !keys.contains(&key.as_str()) {
                return Err(UnknownOptionError {
                    subcommand: subcommand.to_string(),
                    option: key.clone(),
                    accepted: accepted(),
                });
            }
        }
        for flag in &self.flags {
            if !flags.contains(&flag.as_str()) {
                return Err(UnknownOptionError {
                    subcommand: subcommand.to_string(),
                    option: flag.clone(),
                    accepted: accepted(),
                });
            }
        }
        Ok(())
    }

    /// Comma-separated list option parsed as `Vec<T>`.
    pub fn get_list<T: std::str::FromStr>(
        &self,
        key: &str,
        default: &[T],
    ) -> Result<Vec<T>, ParseError>
    where
        T: Clone,
    {
        match self.options.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim().parse().map_err(|_| ParseError {
                        key: key.to_string(),
                        value: s.to_string(),
                        expected: std::any::type_name::<T>(),
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("cluster --n 500 --metric l2 --verbose data.csv");
        assert_eq!(a.subcommand.as_deref(), Some("cluster"));
        assert_eq!(a.get("n"), Some("500"));
        assert_eq!(a.get("metric"), Some("l2"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["data.csv"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --k=10 --delta=0.001");
        assert_eq!(a.get_parsed("k", 0usize).unwrap(), 10);
        assert!((a.get_parsed("delta", 0.0f64).unwrap() - 0.001).abs() < 1e-12);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("x --n abc");
        assert_eq!(a.get_parsed("missing", 7usize).unwrap(), 7);
        let err = a.get_parsed("n", 0usize).unwrap_err();
        assert!(err.to_string().contains("invalid value"));
    }

    #[test]
    fn trailing_flag_not_eating_value() {
        let a = parse("run --fast --n 3");
        assert!(a.flag("fast"));
        assert_eq!(a.get_parsed("n", 0usize).unwrap(), 3);
    }

    #[test]
    fn list_option() {
        let a = parse("sweep --sizes 100,200,300");
        assert_eq!(a.get_list("sizes", &[1usize]).unwrap(), vec![100, 200, 300]);
        assert_eq!(a.get_list("other", &[5usize]).unwrap(), vec![5]);
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }

    #[test]
    fn check_known_rejects_misspelled_options_and_flags() {
        let a = parse("cluster --chunk-nzz 4096 data.mtx");
        let err = a.check_known("cluster", &["chunk-nnz", "k"], &["verbose"]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("--chunk-nzz"), "{msg}");
        assert!(msg.contains("`cluster`"), "{msg}");
        assert!(msg.contains("--chunk-nnz"), "accepted list names the fix: {msg}");

        // a misspelled boolean flag is rejected through the flag table
        let a = parse("cluster --verbos");
        let err = a.check_known("cluster", &["k"], &["verbose"]).unwrap_err();
        assert!(err.to_string().contains("--verbos"), "{err}");

        // the declared sets pass
        let a = parse("cluster --chunk-nnz 4096 --verbose data.mtx");
        a.check_known("cluster", &["chunk-nnz", "k"], &["verbose"]).unwrap();
    }

    #[test]
    fn data_format_parse_and_infer() {
        assert_eq!(DataFormat::parse("csv"), Some(DataFormat::Csv));
        assert_eq!(DataFormat::parse("MTX"), Some(DataFormat::Mtx));
        assert_eq!(DataFormat::parse("idx3"), Some(DataFormat::Idx));
        assert_eq!(DataFormat::parse("parquet"), None);
        assert_eq!(DataFormat::infer("data/matrix.mtx"), DataFormat::Mtx);
        assert_eq!(DataFormat::infer("points.csv"), DataFormat::Csv);
        assert_eq!(DataFormat::infer("train-images-idx3-ubyte"), DataFormat::Csv);
        for f in [DataFormat::Csv, DataFormat::Mtx, DataFormat::Idx] {
            assert_eq!(DataFormat::parse(f.name()), Some(f));
            assert_eq!(f.to_string(), f.name());
        }
    }

    #[test]
    fn sparse_flags_do_not_eat_values() {
        let a = parse("cluster --sparse --density 0.05 --transpose data.mtx");
        assert!(a.flag("sparse"));
        assert!(a.flag("transpose"));
        assert!((a.get_parsed("density", 0.0f64).unwrap() - 0.05).abs() < 1e-12);
        assert_eq!(a.positional, vec!["data.mtx"]);
    }

    #[test]
    fn stream_flag_does_not_eat_values() {
        let a = parse("cluster --stream --chunk-nnz 4096 --limit 500 data.mtx");
        assert!(a.flag("stream"));
        assert_eq!(a.get_parsed("chunk-nnz", 0usize).unwrap(), 4096);
        assert_eq!(a.get_parsed("limit", 0usize).unwrap(), 500);
        assert_eq!(a.positional, vec!["data.mtx"]);
    }
}
