//! Small shared substrates: RNG, matrices, CLI parsing, timing.
//!
//! These exist because the build is fully offline and the crate cache lacks
//! `rand`, `clap`, `ndarray` etc. — so the repo carries its own minimal,
//! tested implementations.

pub mod cli;
pub mod json;
pub mod matrix;
pub mod rng;
pub mod timer;
