//! Deterministic, seedable RNG: xoshiro256++ with splitmix64 seeding.
//!
//! Every stochastic component in the crate (dataset generators, reference
//! sampling in Algorithm 1, CLARANS restarts, the property-test framework)
//! draws from this generator so that every experiment, test and benchmark is
//! reproducible from a single `u64` seed. Independent *streams* (e.g. one
//! per bandit arm, per thread) are derived with [`Rng::fork`], which hashes
//! the parent state with a stream index so streams are statistically
//! decorrelated and order-independent.

/// xoshiro256++ PRNG (Blackman & Vigna). Not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller normal deviate.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a single `u64`.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream keyed on `stream`.
    ///
    /// Forking is position-independent: `fork(i)` yields the same stream no
    /// matter how many draws the parent has made since construction — it
    /// hashes the parent's *seed state* captured at construction time is not
    /// tracked, so callers that need that property should fork from a fresh
    /// `Rng::seed_from(seed)` root (this is what the coordinator does).
    pub fn fork(&self, stream: u64) -> Self {
        let mut sm = self.s[0] ^ self.s[2] ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    /// Uses Lemire's multiply-shift rejection method (unbiased).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as usize;
            }
            // Rejection zone: only reached with probability < n / 2^64.
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        lo + self.below(hi - lo)
    }

    /// Standard normal deviate (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal deviate with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Log-normal deviate: `exp(N(mu, sigma))`.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Poisson deviate (Knuth for small lambda, normal approx for large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let z = self.normal_ms(lambda, lambda.sqrt());
            z.max(0.0).round() as u64
        }
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly. Panics on empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm for
    /// small k, partial shuffle otherwise). Result order is random.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices({n}, {k})");
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            return idx;
        }
        // Floyd's: O(k) expected with a small set.
        let mut chosen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        self.shuffle(&mut out);
        out
    }

    /// Sample `k` indices from `[0, n)` **with replacement**.
    pub fn sample_with_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.below(n)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fork_streams_are_independent_and_stable() {
        let root = Rng::seed_from(9);
        let mut f1 = root.fork(1);
        let mut f1b = root.fork(1);
        let mut f2 = root.fork(2);
        assert_eq!(f1.next_u64(), f1b.next_u64());
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::seed_from(4);
        let n = 10;
        let mut counts = vec![0usize; n];
        let draws = 100_000;
        for _ in 0..draws {
            counts[r.below(n)] += 1;
        }
        let expect = draws as f64 / n as f64;
        for c in counts {
            assert!((c as f64 - expect).abs() < expect * 0.1, "count {c} vs {expect}");
        }
    }

    #[test]
    #[should_panic(expected = "Rng::below(0)")]
    fn below_zero_panics() {
        Rng::seed_from(0).below(0);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(5);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::seed_from(6);
        for &lambda in &[0.5, 3.0, 80.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.08,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(7);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::seed_from(8);
        for &(n, k) in &[(100, 5), (100, 90), (10, 10), (1000, 3)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates in sample");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn sample_with_replacement_in_range() {
        let mut r = Rng::seed_from(9);
        let s = r.sample_with_replacement(7, 1000);
        assert_eq!(s.len(), 1000);
        assert!(s.iter().all(|&i| i < 7));
        // With replacement over 7 values and 1000 draws, all values appear.
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 7);
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::seed_from(10);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 1.0) > 0.0);
        }
    }
}
