//! The one-stop fit facade: `Fit::banditpam().metric(..).seed(..).fit(&data)`.
//!
//! Every [`crate::algorithms::KMedoids`] implementation gets one entry
//! point; the builder assembles the backend (threads, cache), the seeded
//! rng and (for BanditPAM) the validated configuration, runs the fit and
//! wraps the result into a [`KMedoidsModel`] — the caller never touches
//! `NativeBackend`/`Rng` plumbing.

use super::KMedoidsModel;
use crate::algorithms::{make_algorithm, KMedoids};
use crate::coordinator::banditpam::BanditPam;
use crate::coordinator::config::BanditPamConfig;
use crate::data::Dataset;
use crate::distance::Metric;
use crate::error::{Error, Result};
use crate::obs::TraceSink;
use crate::runtime::backend::NativeBackend;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Builder for a k-medoids fit. Construct with one of the per-algorithm
/// entry points ([`Fit::banditpam`], [`Fit::pam`], ...) or by registry
/// name ([`Fit::algorithm`]), chain the knobs, finish with [`Fit::fit`].
///
/// Defaults: `metric = L2`, `k = 5`, `seed = 42`, `threads = 1`, no
/// pairwise cache, paper-default BanditPAM configuration (`meddit` defaults
/// to `k = 1`, the only k it solves).
#[derive(Debug, Clone)]
pub struct Fit {
    pub(crate) algorithm: &'static str,
    pub(crate) metric: Metric,
    pub(crate) k: usize,
    pub(crate) seed: u64,
    pub(crate) threads: usize,
    pub(crate) cache: Option<usize>,
    config: Option<BanditPamConfig>,
    /// Optional structured trace sink ([`TraceSink`]); attached to the
    /// BanditPAM coordinator when the algorithm supports tracing.
    /// Telemetry only — deliberately excluded from [`Fit::fingerprint`]
    /// (tracing never changes the fit, so two fits differing only here
    /// are the same model).
    pub(crate) trace: Option<Arc<TraceSink>>,
}

impl Fit {
    fn with_algorithm(algorithm: &'static str) -> Fit {
        Fit {
            algorithm,
            metric: Metric::L2,
            k: if algorithm == "meddit" { 1 } else { 5 },
            seed: 42,
            threads: 1,
            cache: None,
            config: None,
            trace: None,
        }
    }

    /// BanditPAM (the paper's algorithm; configurable via [`Fit::config`]).
    pub fn banditpam() -> Fit {
        Fit::with_algorithm("banditpam")
    }

    /// Exact PAM (the quality reference).
    pub fn pam() -> Fit {
        Fit::with_algorithm("pam")
    }

    /// FastPAM1 (exact-PAM-equivalent SWAP, O(k) faster).
    pub fn fastpam1() -> Fit {
        Fit::with_algorithm("fastpam1")
    }

    /// FastPAM (near-PAM quality, eager sweeps).
    pub fn fastpam() -> Fit {
        Fit::with_algorithm("fastpam")
    }

    /// FasterPAM (eager randomized-order swaps, Schubert–Rousseeuw).
    pub fn fasterpam() -> Fit {
        Fit::with_algorithm("fasterpam")
    }

    /// OneBatchPAM (frugal PAM on one batch, scored once).
    pub fn onebatchpam() -> Fit {
        Fit::with_algorithm("onebatchpam")
    }

    /// CLARA (PAM on random subsamples).
    pub fn clara() -> Fit {
        Fit::with_algorithm("clara")
    }

    /// CLARANS (randomized neighbor search).
    pub fn clarans() -> Fit {
        Fit::with_algorithm("clarans")
    }

    /// Voronoi iteration (k-means-style alternation).
    pub fn voronoi() -> Fit {
        Fit::with_algorithm("voronoi")
    }

    /// Meddit (the 1-medoid bandit; `k` defaults to 1).
    pub fn meddit() -> Fit {
        Fit::with_algorithm("meddit")
    }

    /// Entry point by registry name — the CLI's `--algo` dispatch.
    pub fn algorithm(name: &str) -> Result<Fit> {
        crate::algorithms::find_algorithm(name).map(|spec| Fit::with_algorithm(spec.name))
    }

    /// Distance metric (default L2).
    pub fn metric(mut self, metric: Metric) -> Fit {
        self.metric = metric;
        self
    }

    /// Number of medoids (default 5; 1 for meddit).
    pub fn k(mut self, k: usize) -> Fit {
        self.k = k;
        self
    }

    /// Rng seed (default 42). Fits are deterministic given the seed,
    /// dataset and configuration — thread count never changes the result.
    pub fn seed(mut self, seed: u64) -> Fit {
        self.seed = seed;
        self
    }

    /// Backend thread count (default 1). Also becomes the model's
    /// predict-time thread count.
    pub fn threads(mut self, threads: usize) -> Fit {
        self.threads = threads.max(1);
        self
    }

    /// Enable the Appendix-2.2 pairwise distance cache with the given soft
    /// entry capacity.
    pub fn cache(mut self, entries: usize) -> Fit {
        self.cache = Some(entries);
        self
    }

    /// BanditPAM configuration (validated at [`Fit::fit`] time; rejected
    /// for the other algorithms rather than silently ignored).
    pub fn config(mut self, config: BanditPamConfig) -> Fit {
        self.config = Some(config);
        self
    }

    /// Attach a structured trace sink: the BanditPAM coordinator emits one
    /// JSONL event per BUILD round and SWAP iteration plus a fit summary
    /// (see `rust/OBS.md`). Ignored by algorithms without tracing support.
    /// Never changes the fit — traced and untraced runs are bitwise
    /// identical (asserted by `tests/property_obs.rs`).
    pub fn trace_sink(mut self, sink: Arc<TraceSink>) -> Fit {
        self.trace = Some(sink);
        self
    }

    /// Upgrade this configuration to the bounded-memory CLARA-style outer
    /// loop: [`BigFit`](crate::model::BigFit) draws subsamples, fits this
    /// algorithm on each in memory, and scores every candidate medoid set
    /// against the full — optionally streamed — dataset window by window.
    pub fn big(self) -> crate::model::BigFit {
        crate::model::BigFit::new(self)
    }

    /// Construct the configured algorithm instance (validating the
    /// BanditPAM config; rejecting a config on any other algorithm).
    /// Shared with the [`crate::model::BigFit`] outer loop, which builds
    /// one fresh instance per subsample.
    pub(crate) fn make_algo(&self) -> Result<Box<dyn KMedoids>> {
        if self.algorithm == "banditpam" {
            let config = self.config.clone().unwrap_or_default();
            config.validate()?;
            let mut algo = BanditPam::new(config);
            algo.set_trace_sink(self.trace.clone());
            Ok(Box::new(algo))
        } else {
            if self.config.is_some() {
                return Err(Error::config(format!(
                    "config(BanditPamConfig) only applies to banditpam (got {})",
                    self.algorithm
                )));
            }
            make_algorithm(self.algorithm)
        }
    }

    /// Run the fit and wrap the result into a [`KMedoidsModel`].
    pub fn fit(&self, data: &Dataset) -> Result<KMedoidsModel> {
        if !self.metric.supports(&data.points) {
            return Err(Error::unsupported(format!(
                "metric {} does not support {} points",
                self.metric,
                data.points.kind()
            )));
        }
        let mut algo = self.make_algo()?;
        let mut backend =
            NativeBackend::new(&data.points, self.metric).with_threads(self.threads);
        if let Some(entries) = self.cache {
            backend = backend.with_cache(entries);
        }
        let mut rng = Rng::seed_from(self.seed);
        let clustering = algo.fit(&backend, self.k, &mut rng)?;
        let model = KMedoidsModel::from_fit(
            &data.points,
            self.metric,
            clustering,
            self.algorithm,
            self.fingerprint(),
        )?;
        Ok(model.with_threads(self.threads))
    }

    /// The reproducibility fingerprint recorded into the model: every knob
    /// that determines the fit, as stable `key=value` pairs.
    pub(crate) fn fingerprint(&self) -> String {
        let config = match (&self.config, self.algorithm) {
            (Some(c), _) => format!("{c:?}"),
            (None, "banditpam") => format!("{:?}", BanditPamConfig::default()),
            (None, _) => "default".to_string(),
        };
        format!(
            "algo={} metric={} k={} seed={} threads={} cache={} config={config}",
            self.algorithm,
            self.metric,
            self.k,
            self.seed,
            self.threads,
            self.cache.map_or("none".to_string(), |c| c.to_string()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::runtime::backend::NativeBackend;

    #[test]
    fn facade_matches_hand_assembled_fit_bitwise() {
        let ds = synthetic::gmm(&mut Rng::seed_from(1), 80, 8, 4, 3.0);
        let model = Fit::banditpam().metric(Metric::L2).seed(7).k(4).fit(&ds).unwrap();
        // the long way around, same seed
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = BanditPam::new(BanditPamConfig::default())
            .fit(&backend, 4, &mut Rng::seed_from(7))
            .unwrap();
        assert_eq!(model.clustering().medoids, fit.medoids);
        assert_eq!(model.clustering().assignments, fit.assignments);
        assert_eq!(model.loss().to_bits(), fit.loss.to_bits());
    }

    #[test]
    fn every_registry_algorithm_has_a_facade_entry() {
        let ds = synthetic::gmm(&mut Rng::seed_from(2), 50, 6, 3, 3.0);
        let entries = [
            Fit::banditpam(),
            Fit::pam(),
            Fit::fastpam1(),
            Fit::fastpam(),
            Fit::fasterpam(),
            Fit::clara(),
            Fit::onebatchpam(),
            Fit::clarans(),
            Fit::voronoi(),
            Fit::meddit(),
        ];
        assert_eq!(entries.len(), crate::algorithms::REGISTRY.len());
        for fit in entries {
            let k = if fit.algorithm == "meddit" { 1 } else { 3 };
            let model = fit.k(k).seed(3).fit(&ds).unwrap();
            assert!(model.k() >= 1, "{}", model.algorithm());
            assert_eq!(model.n_train(), 50);
        }
        // by-name entry mirrors the registry
        assert!(Fit::algorithm("pam").is_ok());
        assert!(Fit::algorithm("kmeans").is_err());
    }

    #[test]
    fn config_on_non_banditpam_is_rejected() {
        let ds = synthetic::gmm(&mut Rng::seed_from(3), 30, 4, 2, 3.0);
        let err = Fit::pam().config(BanditPamConfig::default()).fit(&ds).unwrap_err();
        assert_eq!(err.kind(), "config");
        // and an invalid config is rejected before any work happens
        let err = Fit::banditpam()
            .config(BanditPamConfig { batch_size: 0, ..Default::default() })
            .fit(&ds)
            .unwrap_err();
        assert_eq!(err.kind(), "config");
    }

    #[test]
    fn unsupported_metric_storage_is_a_clean_error() {
        let trees = synthetic::hoc4_like(&mut Rng::seed_from(4), 20);
        let err = Fit::banditpam().metric(Metric::L2).fit(&trees);
        // L2 over trees: rejected, not panicked
        assert_eq!(err.unwrap_err().kind(), "unsupported");
        // tree edit over trees through the facade works end to end
        let model = Fit::banditpam().metric(Metric::TreeEdit).k(3).seed(1).fit(&trees).unwrap();
        assert_eq!(model.k(), 3);
        assert_eq!(model.dim(), None);
        // ... and predicts its own training set bitwise
        let pred = model.predict(&trees.points).unwrap();
        assert_eq!(&pred, &model.clustering().assignments);
        // but has no serialized form
        assert_eq!(model.to_bytes().unwrap_err().kind(), "unsupported");
    }
}
