//! The fitted-model layer: [`KMedoidsModel`] and the [`Fit`] builder.
//!
//! The paper's pitch is that k-medoids centers are *actual data points*
//! supporting *arbitrary metrics* — which makes the fitted medoid set a
//! reusable artifact, not just indices into a dataset the caller must keep
//! alive. [`KMedoidsModel`] owns the extracted medoid points (dense rows
//! or CSR rows — or cloned trees for the tree-edit metric), the metric,
//! and the training [`Clustering`] metadata, and serves batch
//! out-of-sample assignment through the same one-to-many row kernels the
//! fit used ([`crate::runtime::backend::NativeBackend::block_vs`]):
//! predicting the training set reproduces the stored training assignments
//! **bit for bit**.
//!
//! Vector-storage models serialize to a versioned little-endian binary
//! format ([`KMedoidsModel::save`] / [`KMedoidsModel::load`], documented
//! in `rust/MODEL.md`); malformed files produce clean
//! [`Error::Model`](crate::error::Error::Model) errors, never panics.
//!
//! [`Fit`] is the one-stop front door: pick an algorithm, chain the knobs,
//! fit a [`crate::data::Dataset`] — no hand-assembled backend/rng/config.
//!
//! ```no_run
//! use banditpam::prelude::*;
//!
//! let mut rng = Rng::seed_from(7);
//! let data = synthetic::gmm(&mut rng, 200, 16, 5, 3.0);
//! let model = Fit::banditpam().metric(Metric::L2).seed(7).k(5).fit(&data)?;
//! let assignments = model.predict(&data.points)?; // == training assignments
//! model.save(std::path::Path::new("gmm.bpmodel"))?;
//! # Ok::<(), banditpam::Error>(())
//! ```

mod bigfit;
mod fit;
mod format;

pub use bigfit::{BigFit, BigFitStats, SampleTrace};
pub use fit::Fit;

use crate::algorithms::Clustering;
use crate::data::Points;
use crate::distance::Metric;
use crate::error::{Error, Result};
use crate::runtime::backend::{assign_against, NativeBackend};
use std::path::Path;

/// A fitted k-medoids model, decoupled from its training data.
///
/// Holds the k medoid points themselves (owned), the metric, and the
/// training-fit metadata. Construct through [`Fit`] (preferred),
/// [`KMedoidsModel::from_fit`] (when you already ran a
/// [`crate::algorithms::KMedoids`] by hand), or [`KMedoidsModel::load`].
#[derive(Debug, Clone)]
pub struct KMedoidsModel {
    /// The k extracted medoid points, in `clustering.medoids` order
    /// (ascending training index).
    medoid_points: Points,
    metric: Metric,
    /// The training fit: medoid *training indices*, per-training-point
    /// assignments, loss, stats.
    clustering: Clustering,
    /// [`crate::algorithms::KMedoids::name`] of the producing algorithm.
    algorithm: String,
    /// Reproducibility fingerprint of the producing configuration
    /// (free-form single line; [`Fit`] writes `key=value` pairs).
    fingerprint: String,
    /// Training set size the clustering metadata refers to.
    n_train: usize,
    /// Predict-time thread count (runtime knob; not serialized).
    threads: usize,
}

impl KMedoidsModel {
    /// Build a model from a finished fit: extracts the medoid rows of
    /// `points` named by `clustering.medoids` into owned storage.
    ///
    /// Errors when the clustering and the point set disagree (an index out
    /// of range, assignment list of the wrong length or naming a
    /// nonexistent medoid slot) or the metric does not support the
    /// storage.
    pub fn from_fit(
        points: &Points,
        metric: Metric,
        clustering: Clustering,
        algorithm: impl Into<String>,
        fingerprint: impl Into<String>,
    ) -> Result<KMedoidsModel> {
        let n = points.len();
        // Range-check before `select` (which would panic on a bad index);
        // everything else is validated by `from_extracted`.
        if let Some(&bad) = clustering.medoids.iter().find(|&&m| m >= n) {
            return Err(Error::invalid_argument(format!(
                "medoid index {bad} out of range for n = {n}"
            )));
        }
        if clustering.medoids.is_empty() {
            return Err(Error::invalid_argument("clustering has no medoids"));
        }
        let medoid_points = points.select(&clustering.medoids);
        Self::from_extracted(medoid_points, metric, clustering, n, algorithm, fingerprint)
    }

    /// Build a model from already-extracted medoid rows: the
    /// [`crate::model::BigFit`] entry point, where the full training set
    /// was streamed and only the k medoid rows (bit-copies of the
    /// originals) remain resident. `clustering.medoids` still holds
    /// *training-set* indices into the `n_train`-row dataset the
    /// assignments cover; `medoid_points` must hold the corresponding rows
    /// in the same (ascending) order.
    pub fn from_extracted(
        medoid_points: Points,
        metric: Metric,
        clustering: Clustering,
        n_train: usize,
        algorithm: impl Into<String>,
        fingerprint: impl Into<String>,
    ) -> Result<KMedoidsModel> {
        let k = clustering.medoids.len();
        if k == 0 {
            return Err(Error::invalid_argument("clustering has no medoids"));
        }
        if medoid_points.len() != k {
            return Err(Error::invalid_argument(format!(
                "{} medoid rows for {k} medoid indices",
                medoid_points.len()
            )));
        }
        if !metric.supports(&medoid_points) {
            return Err(Error::unsupported(format!(
                "metric {metric} does not support {} points",
                medoid_points.kind()
            )));
        }
        if let Some(&bad) = clustering.medoids.iter().find(|&&m| m >= n_train) {
            return Err(Error::invalid_argument(format!(
                "medoid index {bad} out of range for n = {n_train}"
            )));
        }
        // `Clustering::finalize` sorts medoids ascending and assignments
        // index that order; the binary format reader enforces the same
        // invariant. Reject hand-assembled unsorted sets here so a model
        // that saves can always be loaded back.
        if clustering.medoids.windows(2).any(|w| w[0] >= w[1]) {
            return Err(Error::invalid_argument(
                "medoid indices must be strictly increasing (Clustering::finalize \
                 order) — assignments index positions in that order",
            ));
        }
        if clustering.assignments.len() != n_train {
            return Err(Error::invalid_argument(format!(
                "assignment list has {} entries for n = {n_train}",
                clustering.assignments.len()
            )));
        }
        if let Some(&bad) = clustering.assignments.iter().find(|&&a| a >= k) {
            return Err(Error::invalid_argument(format!(
                "assignment {bad} out of range for k = {k}"
            )));
        }
        Ok(KMedoidsModel {
            medoid_points,
            metric,
            clustering,
            algorithm: algorithm.into(),
            fingerprint: fingerprint.into(),
            n_train,
            threads: 1,
        })
    }

    /// Set the predict-time thread count (runtime knob, not serialized;
    /// thread count never changes predicted bits).
    pub fn with_threads(mut self, threads: usize) -> KMedoidsModel {
        self.threads = threads.max(1);
        self
    }

    /// Number of medoids.
    pub fn k(&self) -> usize {
        self.clustering.medoids.len()
    }

    /// The fit metric.
    pub fn metric(&self) -> Metric {
        self.metric
    }

    /// Feature dimensionality (`None` for tree-medoid models).
    pub fn dim(&self) -> Option<usize> {
        self.medoid_points.dim()
    }

    /// Training set size.
    pub fn n_train(&self) -> usize {
        self.n_train
    }

    /// Producing algorithm name ("banditpam", "pam", ...).
    pub fn algorithm(&self) -> &str {
        &self.algorithm
    }

    /// Reproducibility fingerprint of the producing configuration.
    pub fn config_fingerprint(&self) -> &str {
        &self.fingerprint
    }

    /// The owned medoid points (k rows, `clustering().medoids` order).
    pub fn medoid_points(&self) -> &Points {
        &self.medoid_points
    }

    /// The training fit: medoid training indices, training assignments,
    /// loss and stats.
    pub fn clustering(&self) -> &Clustering {
        &self.clustering
    }

    /// Training loss (Eq. 1).
    pub fn loss(&self) -> f64 {
        self.clustering.loss
    }

    /// A reusable prediction handle: holds the metric backend — and, with
    /// [`KMedoidsModel::with_threads`] above 1, its **persistent thread
    /// pool** — across batches. One-shot [`KMedoidsModel::predict`] calls
    /// build and tear down a pool each time; a serving loop should hold
    /// one `Predictor` instead (same results, bit for bit).
    pub fn predictor(&self) -> Predictor<'_> {
        Predictor {
            model: self,
            backend: NativeBackend::new(&self.medoid_points, self.metric)
                .with_threads(self.threads),
        }
    }

    /// Like [`KMedoidsModel::predictor`], but computing through an
    /// existing shared [`ThreadPool`](crate::runtime::pool::ThreadPool)
    /// instead of spawning one. The serve subsystem holds one warm pool
    /// for the whole process and builds a short-lived `Predictor` per
    /// batch; thread count never changes predicted bits, so results are
    /// identical to [`KMedoidsModel::predict`].
    pub fn predictor_with_pool(
        &self,
        pool: std::sync::Arc<crate::runtime::pool::ThreadPool>,
    ) -> Predictor<'_> {
        Predictor {
            model: self,
            backend: NativeBackend::new(&self.medoid_points, self.metric).with_pool(pool),
        }
    }

    /// Assign each query point to its nearest medoid; `out[i]` indexes
    /// [`KMedoidsModel::clustering`]`.medoids`. See
    /// [`KMedoidsModel::predict_with_dists`].
    pub fn predict(&self, queries: &Points) -> Result<Vec<usize>> {
        Ok(self.predict_with_dists(queries)?.0)
    }

    /// Assign each query point to its nearest medoid, also returning the
    /// distance to it.
    ///
    /// Queries must use the same storage kind and feature space as the
    /// model. Computation runs through the same one-to-many row kernels
    /// and first-minimum tie-breaking as the training-side
    /// `loss_and_assignments`, so predicting the training points is
    /// bitwise-equal to the stored training assignments — across metrics,
    /// storage kinds and thread counts.
    ///
    /// One carve-out: a degenerate `k == n` fit stores identity
    /// assignments without evaluating distances, so on data containing
    /// duplicate (or cosine-parallel) points its stored labels can pick a
    /// *later* zero-distance medoid than predict's tie-break would — see
    /// [`Clustering::each_point_its_own_medoid`]. Distances are exactly
    /// zero under both labelings.
    pub fn predict_with_dists(&self, queries: &Points) -> Result<(Vec<usize>, Vec<f64>)> {
        self.predictor().predict_with_dists(queries)
    }

    /// Serialize to the versioned binary model format (see
    /// `rust/MODEL.md`). Tree-medoid models have no on-disk form.
    pub fn save(&self, path: &Path) -> Result<()> {
        let bytes = self.to_bytes()?;
        std::fs::write(path, bytes)
            .map_err(|e| Error::model(format!("writing {}: {e}", path.display())))
    }

    /// Deserialize a model written by [`KMedoidsModel::save`]. Malformed
    /// input of any kind — bad magic/version, lying lengths, corrupt CSR
    /// payload — returns [`Error::Model`], never panics, and never
    /// allocates more than the file's own size promises.
    pub fn load(path: &Path) -> Result<KMedoidsModel> {
        let bytes = std::fs::read(path)
            .map_err(|e| Error::model(format!("reading {}: {e}", path.display())))?;
        Self::from_bytes(&bytes)
    }

    /// [`KMedoidsModel::save`] to an in-memory buffer.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        format::write(self)
    }

    /// [`KMedoidsModel::load`] from an in-memory buffer.
    pub fn from_bytes(bytes: &[u8]) -> Result<KMedoidsModel> {
        format::read(bytes)
    }
}

/// A prediction handle bound to a [`KMedoidsModel`], created by
/// [`KMedoidsModel::predictor`]. Holds the resolved backend (and its
/// persistent thread pool) so repeated batches pay no per-call setup —
/// the serving-loop counterpart of the one-shot `predict` methods, with
/// bitwise-identical results.
pub struct Predictor<'m> {
    model: &'m KMedoidsModel,
    backend: NativeBackend<'m>,
}

impl Predictor<'_> {
    /// Batch assignment; see [`KMedoidsModel::predict`].
    pub fn predict(&self, queries: &Points) -> Result<Vec<usize>> {
        Ok(self.predict_with_dists(queries)?.0)
    }

    /// Batch assignment with distances; see
    /// [`KMedoidsModel::predict_with_dists`] for the parity contract.
    pub fn predict_with_dists(&self, queries: &Points) -> Result<(Vec<usize>, Vec<f64>)> {
        let medoids = &self.model.medoid_points;
        if queries.kind() != medoids.kind() {
            return Err(Error::unsupported(format!(
                "query storage {} does not match the model's {} medoids \
                 (convert with Points::to_dense/to_sparse first)",
                queries.kind(),
                medoids.kind()
            )));
        }
        if let (Some(qd), Some(md)) = (queries.dim(), medoids.dim()) {
            if qd != md {
                return Err(Error::invalid_argument(format!(
                    "query dimension {qd} does not match the model's {md}"
                )));
            }
        }
        if queries.is_empty() {
            return Ok((Vec::new(), Vec::new()));
        }
        Ok(assign_against(&self.backend, queries))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Clustering, FitStats};
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    fn model_over_gmm() -> (crate::data::Dataset, KMedoidsModel) {
        let ds = synthetic::gmm(&mut Rng::seed_from(1), 40, 8, 3, 3.0);
        let model = Fit::banditpam().metric(Metric::L2).seed(5).k(3).fit(&ds).unwrap();
        (ds, model)
    }

    #[test]
    fn from_fit_validates_consistency() {
        let ds = synthetic::gmm(&mut Rng::seed_from(2), 10, 4, 2, 2.0);
        let good = Clustering {
            medoids: vec![1, 4],
            assignments: vec![0; 10],
            loss: 1.0,
            stats: FitStats::default(),
        };
        assert!(KMedoidsModel::from_fit(&ds.points, Metric::L2, good.clone(), "pam", "")
            .is_ok());
        let cases = [
            Clustering { medoids: vec![], ..good.clone() },
            Clustering { medoids: vec![1, 10], ..good.clone() },
            // unsorted / duplicate medoids save fine but could never load
            // back (the format requires strictly increasing indices)
            Clustering { medoids: vec![4, 1], ..good.clone() },
            Clustering { medoids: vec![1, 1], ..good.clone() },
            Clustering { assignments: vec![0; 9], ..good.clone() },
            Clustering { assignments: vec![2; 10], ..good.clone() },
        ];
        for (i, bad) in cases.into_iter().enumerate() {
            assert!(
                KMedoidsModel::from_fit(&ds.points, Metric::L2, bad, "pam", "").is_err(),
                "case {i} must be rejected"
            );
        }
    }

    #[test]
    fn predict_rejects_mismatched_queries() {
        let (ds, model) = model_over_gmm();
        // storage mismatch
        let sp = ds.points.to_sparse().unwrap();
        assert_eq!(model.predict(&sp).unwrap_err().kind(), "unsupported");
        // dimension mismatch
        let wrong = synthetic::gmm(&mut Rng::seed_from(3), 5, 9, 2, 1.0);
        assert_eq!(
            model.predict(&wrong.points).unwrap_err().kind(),
            "invalid_argument"
        );
        // empty queries are fine
        let empty = crate::data::Points::Dense(crate::util::matrix::Matrix::zeros(0, 8));
        assert_eq!(model.predict(&empty).unwrap(), Vec::<usize>::new());
    }

    /// A reused `Predictor` (one backend + pool across batches) returns
    /// the same bits as the one-shot predict path.
    #[test]
    fn predictor_reuse_matches_one_shot_predict() {
        let (ds, model) = model_over_gmm();
        let model = model.with_threads(4);
        let batches: Vec<_> = (0..3)
            .map(|i| ds.select(&[(i * 7) % 40, (i * 11) % 40, (i * 13) % 40]))
            .collect();
        let served = model.predictor();
        for batch in &batches {
            let (a_served, d_served) = served.predict_with_dists(&batch.points).unwrap();
            let (a_once, d_once) = model.predict_with_dists(&batch.points).unwrap();
            assert_eq!(a_served, a_once);
            let b1: Vec<u64> = d_served.iter().map(|d| d.to_bits()).collect();
            let b2: Vec<u64> = d_once.iter().map(|d| d.to_bits()).collect();
            assert_eq!(b1, b2);
            assert_eq!(served.predict(&batch.points).unwrap(), a_served);
        }
    }

    #[test]
    fn metadata_accessors_round_through() {
        let (ds, model) = model_over_gmm();
        assert_eq!(model.k(), 3);
        assert_eq!(model.metric(), Metric::L2);
        assert_eq!(model.dim(), Some(8));
        assert_eq!(model.n_train(), 40);
        assert_eq!(model.algorithm(), "banditpam");
        assert!(model.config_fingerprint().contains("seed=5"));
        assert_eq!(model.medoid_points().len(), 3);
        assert_eq!(model.clustering().assignments.len(), ds.len());
        assert!(model.loss() > 0.0);
    }
}
