//! BigFit: bounded-memory CLARA-style training over datasets that never
//! sit in memory as a whole.
//!
//! CLARA's insight (PAM on subsamples, score every candidate on the full
//! dataset, keep the best) only needs two memory-bounded ingredients: a
//! subsample draw and a full-dataset evaluation. The streamed subsampler
//! ([`CsrChunkReader::subsample_rows`]) provides the first at
//! `selected + one window` residency, and the window-at-a-time evaluation
//! primitive ([`loss_and_assignments_streamed`]) provides the second at
//! `k medoid rows + one window` residency — so the whole outer loop runs
//! with peak value residency `max(sample + window, medoids + window)`,
//! never the full matrix.
//!
//! [`BigFit`] wraps any registered algorithm (a configured [`Fit`],
//! upgraded via [`Fit::big`]): each round draws one subsample, fits the
//! inner algorithm on it in memory, extracts the winning medoid *rows*
//! (bit-copies of the full dataset's rows), drops the sample, and scores
//! the candidate over the full dataset window by window. The in-memory
//! ([`BigFit::fit`]) and streamed ([`BigFit::fit_streamed`]) paths are
//! **bitwise-identical by construction**:
//!
//! * the index draw is the same single `rng.sample_indices(n, ssize)` call,
//!   and the streamed sample assembles to the same bits as
//!   `Points::select` on those indices (pinned since the PR 4 parity
//!   suite), so the inner fits see identical inputs and consume identical
//!   rng — draw/fit/eval interleave per sample, keeping the streams in
//!   lockstep;
//! * evaluation folds the same cross row kernels in the same global row
//!   order through [`WindowFold`](crate::runtime::backend::WindowFold),
//!   where window boundaries never change bits.
//!
//! The result is a normal [`KMedoidsModel`] built from the extracted
//! medoid rows ([`KMedoidsModel::from_extracted`]): predict, persistence
//! and serving work unchanged, and predicting the training stream
//! reproduces the stored assignments bit for bit.

use super::{Fit, KMedoidsModel};
use crate::algorithms::clara::effective_sample_size;
use crate::algorithms::{Clustering, FitStats, KMedoids};
use crate::data::stream::{CsrChunkReader, StreamOptions, StreamStats};
use crate::data::{Dataset, Points};
use crate::dist::WorkerPool;
use crate::error::{Error, Result};
use crate::obs::{TraceSink, TraceValue};
use crate::runtime::backend::{loss_and_assignments_streamed, DistanceBackend, NativeBackend};
use crate::runtime::pool::ThreadPool;
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Rows per evaluation window on the in-memory path. Any value gives the
/// same bits (per-reference-independent kernels, global-row-order fold);
/// this one keeps the window copy around a few MiB of dense f32s.
const EVAL_WINDOW_ROWS: usize = 4096;

/// The CLARA-style outer loop around a configured [`Fit`]. Construct via
/// [`Fit::big`], tune with [`BigFit::samples`] / [`BigFit::sample_size`],
/// run with [`BigFit::fit`] (in-memory dataset) or
/// [`BigFit::fit_streamed`] (out-of-core `.mtx`).
#[derive(Debug, Clone)]
pub struct BigFit {
    inner: Fit,
    samples: usize,
    sample_size: usize,
}

/// Per-sample trace of one BigFit round, for the wall-clock trajectory.
#[derive(Debug, Clone)]
pub struct SampleTrace {
    /// Round index, `0..samples`.
    pub sample: usize,
    /// Full-dataset loss of this round's candidate medoid set.
    pub loss: f64,
    /// Seconds drawing (and, streamed, collecting) the subsample.
    pub subsample_secs: f64,
    /// Seconds fitting the inner algorithm on the sample.
    pub fit_secs: f64,
    /// Seconds scoring the candidate over the full dataset.
    pub eval_secs: f64,
}

/// Memory/time accounting for a BigFit run — the numbers the
/// bounded-memory claim is about.
#[derive(Debug, Clone)]
pub struct BigFitStats {
    /// Rounds run.
    pub samples: usize,
    /// Effective subsample size (after the `40 + 2k` default / clamping).
    pub sample_size: usize,
    /// Full dataset rows.
    pub n_rows: usize,
    /// Raw entries of the full dataset (sparse sources; 0 for dense).
    pub total_nnz: usize,
    /// Largest single row-window, in raw entries (streamed; 0 in-memory).
    pub peak_window_nnz: usize,
    /// Peak resident values across every pass: streamed, the largest
    /// `selected + window` / `medoids + window` working set; in-memory,
    /// the whole matrix (which *is* resident there).
    pub peak_resident_nnz: usize,
    /// One entry per round, in order.
    pub trajectory: Vec<SampleTrace>,
    /// Total wall-clock seconds.
    pub wall_secs: f64,
}

/// What the outer loop needs from a dataset: a subsample draw and a
/// window-by-window candidate evaluation, each reporting residency.
trait Source {
    /// Full dataset rows.
    fn n(&self) -> usize;
    /// Draw `ssize` rows without replacement — the identical rng call and
    /// resulting bits on every implementation.
    fn draw(&mut self, ssize: usize, rng: &mut Rng) -> Result<(Points, Vec<usize>)>;
    /// Score `medoid_backend`'s k rows against the full dataset.
    /// `medoid_nnz` is the candidate's resident raw-entry count, folded
    /// into the residency peak alongside the windows.
    fn eval(
        &mut self,
        medoid_backend: &NativeBackend<'_>,
        medoid_nnz: usize,
    ) -> Result<(f64, Vec<usize>)>;
    /// Raw entries of the full dataset (0 when dense / unknown).
    fn total_nnz(&self) -> usize;
    /// Largest row-window seen (0 in-memory).
    fn peak_window_nnz(&self) -> usize;
    /// Peak resident raw entries across the passes so far.
    fn peak_resident_nnz(&self) -> usize;
    /// Attach a trace sink for per-window eval events (no-op for sources
    /// that don't emit any).
    fn set_trace(&mut self, _sink: Option<Arc<TraceSink>>) {}
}

/// Raw entries a [`Points`] holds (dense/tree storage reports 0 — the
/// residency accounting is a sparse-workload concern).
fn nnz_of(points: &Points) -> usize {
    match points {
        Points::Sparse(m) => m.nnz(),
        _ => 0,
    }
}

/// In-memory source: draws via `Points::select` on the one
/// `sample_indices` call, evaluates over fixed-size row ranges of the
/// resident matrix — the same window-fold code path the streamed source
/// uses, so dense and CSV data run through identical evaluation code.
struct MemSource<'d> {
    points: &'d Points,
    /// When set, candidate evaluation is sharded over the pool instead of
    /// folded locally — bitwise the same result (the pool's score path
    /// folds per-row partials in global row order through the same
    /// kernels; see `rust/DIST.md`), and the eval counter still lands on
    /// `medoid_backend.counter()` with the exact single-process count.
    workers: Option<&'d WorkerPool<'d>>,
}

impl Source for MemSource<'_> {
    fn n(&self) -> usize {
        self.points.len()
    }

    fn draw(&mut self, ssize: usize, rng: &mut Rng) -> Result<(Points, Vec<usize>)> {
        let idx = rng.sample_indices(self.points.len(), ssize);
        // Draw order, not sorted — `CsrChunkReader::subsample_rows`
        // assembles in draw order, and bitwise parity needs both paths to
        // agree on row order.
        Ok((self.points.select(&idx), idx))
    }

    fn eval(
        &mut self,
        medoid_backend: &NativeBackend<'_>,
        _medoid_nnz: usize,
    ) -> Result<(f64, Vec<usize>)> {
        if let Some(pool) = self.workers {
            return pool.score(medoid_backend.points(), medoid_backend.counter());
        }
        let n = self.points.len();
        let mut start = 0usize;
        loss_and_assignments_streamed(medoid_backend, n, || {
            if start == n {
                return Ok(None);
            }
            let end = (start + EVAL_WINDOW_ROWS).min(n);
            let idx: Vec<usize> = (start..end).collect();
            let window = self.points.select(&idx);
            let s = start;
            start = end;
            Ok(Some((s, window)))
        })
    }

    fn total_nnz(&self) -> usize {
        nnz_of(self.points)
    }

    fn peak_window_nnz(&self) -> usize {
        0
    }

    fn peak_resident_nnz(&self) -> usize {
        // The matrix is simply resident here; report that honestly.
        nnz_of(self.points)
    }
}

/// Out-of-core source: every draw/eval re-opens the `.mtx` through a
/// fresh [`CsrChunkReader`] (both consumption patterns require one), and
/// the reader's own residency counters accumulate into the run-wide peak.
struct StreamSource {
    path: PathBuf,
    opts: StreamOptions,
    rows: usize,
    kept_nnz: usize,
    peak_window_nnz: usize,
    peak_resident_nnz: usize,
    trace: Option<Arc<TraceSink>>,
}

impl StreamSource {
    fn new(path: &Path, opts: StreamOptions) -> Result<StreamSource> {
        let reader = CsrChunkReader::open(path, opts.clone())?;
        let stats = reader.stats();
        Ok(StreamSource {
            path: path.to_path_buf(),
            opts,
            rows: reader.rows(),
            kept_nnz: stats.kept_nnz,
            peak_window_nnz: stats.peak_window_nnz,
            peak_resident_nnz: 0,
            trace: None,
        })
    }

    fn reopen(&self) -> Result<CsrChunkReader> {
        let reader = CsrChunkReader::open(&self.path, self.opts.clone())?;
        if reader.rows() != self.rows {
            return Err(Error::data(format!(
                "{}: row count changed between passes ({} -> {})",
                self.path.display(),
                self.rows,
                reader.rows()
            )));
        }
        Ok(reader)
    }

    fn merge(&mut self, stats: &StreamStats, extra_resident: usize) {
        self.peak_window_nnz = self.peak_window_nnz.max(stats.peak_window_nnz);
        self.peak_resident_nnz =
            self.peak_resident_nnz.max(stats.peak_resident_nnz + extra_resident);
    }
}

impl Source for StreamSource {
    fn n(&self) -> usize {
        self.rows
    }

    fn draw(&mut self, ssize: usize, rng: &mut Rng) -> Result<(Points, Vec<usize>)> {
        let mut reader = self.reopen()?;
        let (matrix, idx) = reader.subsample_rows(ssize, rng)?;
        self.merge(&reader.stats(), 0);
        Ok((Points::Sparse(matrix), idx))
    }

    fn eval(
        &mut self,
        medoid_backend: &NativeBackend<'_>,
        medoid_nnz: usize,
    ) -> Result<(f64, Vec<usize>)> {
        let mut reader = self.reopen()?;
        let sink = self.trace.clone();
        let out = loss_and_assignments_streamed(medoid_backend, self.rows, || {
            Ok(reader.next_window()?.map(|w| {
                if let Some(s) = &sink {
                    s.emit(
                        "eval_window",
                        &[
                            ("start_row", TraceValue::from(w.start_row)),
                            ("rows", TraceValue::from(w.matrix.rows())),
                            ("nnz", TraceValue::from(w.matrix.nnz())),
                        ],
                    );
                }
                (w.start_row, Points::Sparse(w.matrix))
            }))
        })?;
        self.merge(&reader.stats(), medoid_nnz);
        Ok(out)
    }

    fn total_nnz(&self) -> usize {
        self.kept_nnz
    }

    fn peak_window_nnz(&self) -> usize {
        self.peak_window_nnz
    }

    fn peak_resident_nnz(&self) -> usize {
        self.peak_resident_nnz
    }

    fn set_trace(&mut self, sink: Option<Arc<TraceSink>>) {
        self.trace = sink;
    }
}

impl BigFit {
    /// Wrap a configured [`Fit`] (see [`Fit::big`]). Defaults: 5 samples,
    /// classic `40 + 2k` sample size.
    pub fn new(inner: Fit) -> BigFit {
        BigFit { inner, samples: 5, sample_size: 0 }
    }

    /// Number of subsample rounds (default 5; must be >= 1).
    pub fn samples(mut self, samples: usize) -> BigFit {
        self.samples = samples;
        self
    }

    /// Subsample size override; 0 (default) = the classic `40 + 2k`,
    /// clamped to `n` either way.
    pub fn sample_size(mut self, sample_size: usize) -> BigFit {
        self.sample_size = sample_size;
        self
    }

    /// Run over an in-memory dataset. Same outer loop — and, seeded
    /// identically over the same data, bitwise the same result — as
    /// [`BigFit::fit_streamed`].
    pub fn fit(&self, data: &Dataset) -> Result<KMedoidsModel> {
        Ok(self.fit_with_stats(data)?.0)
    }

    /// [`BigFit::fit`] also returning the [`BigFitStats`] accounting.
    pub fn fit_with_stats(&self, data: &Dataset) -> Result<(KMedoidsModel, BigFitStats)> {
        let mut src = MemSource { points: &data.points, workers: None };
        self.run(&mut src)
    }

    /// [`BigFit::fit_with_stats`] with candidate evaluation sharded over
    /// a [`WorkerPool`] — the full-dataset scoring pass (the dominant
    /// cost at scale) is distributed; sample draws and inner fits stay
    /// local. The pool must be built over `data.points` with the fit's
    /// metric. Bitwise-identical to the single-process run: same medoids,
    /// loss bits and eval counts.
    pub fn fit_with_workers(
        &self,
        data: &Dataset,
        pool: &WorkerPool<'_>,
    ) -> Result<(KMedoidsModel, BigFitStats)> {
        if pool.n_rows() != data.points.len() {
            return Err(Error::invalid_argument(format!(
                "dist: pool shards {} rows but the dataset has {}",
                pool.n_rows(),
                data.points.len()
            )));
        }
        let mut src = MemSource { points: &data.points, workers: Some(pool) };
        self.run(&mut src)
    }

    /// Run out-of-core over a `.mtx` file: the dataset is consumed as
    /// row-windows under `opts.chunk_nnz` and is never resident as a
    /// whole. Bitwise-identical to [`BigFit::fit`] on the loaded dataset
    /// with the same seed.
    pub fn fit_streamed(
        &self,
        path: &Path,
        opts: &StreamOptions,
    ) -> Result<(KMedoidsModel, BigFitStats)> {
        let mut src = StreamSource::new(path, opts.clone())?;
        self.run(&mut src)
    }

    /// The shared outer loop: draw -> fit -> extract medoid rows -> drop
    /// sample -> score streamed, keeping the strictly best candidate.
    fn run(&self, src: &mut dyn Source) -> Result<(KMedoidsModel, BigFitStats)> {
        let total = Timer::start();
        if self.samples == 0 {
            return Err(Error::invalid_argument("bigfit requires samples >= 1"));
        }
        let n = src.n();
        if n == 0 {
            return Err(Error::invalid_argument("bigfit over an empty dataset"));
        }
        let k = self.inner.k;
        if k == 0 {
            return Err(Error::invalid_argument("k must be >= 1 (got 0)"));
        }
        let ssize = effective_sample_size(self.sample_size, k, n);
        if ssize <= k {
            return Err(Error::invalid_argument(format!(
                "sample size {ssize} must exceed k {k} (n = {n})"
            )));
        }
        let metric = self.inner.metric;
        let threads = self.inner.threads;
        // One pool for every backend the loop builds (sample fits and
        // candidate evaluations); thread count never changes bits.
        let pool: Option<Arc<ThreadPool>> =
            (threads > 1).then(|| Arc::new(ThreadPool::new(threads)));
        src.set_trace(self.inner.trace.clone());
        let mut rng = Rng::seed_from(self.inner.seed);

        let mut best: Option<(f64, Vec<usize>, Vec<usize>, Points)> = None;
        let mut build_evals = 0u64;
        let mut eval_evals = 0u64;
        let mut swap_iters = 0usize;
        let mut swaps_applied = 0usize;
        let mut trajectory = Vec::with_capacity(self.samples);

        for sample in 0..self.samples {
            let t_draw = Timer::start();
            let (sample_points, idx) = src.draw(ssize, &mut rng)?;
            let subsample_secs = t_draw.secs();
            if !metric.supports(&sample_points) {
                return Err(Error::unsupported(format!(
                    "metric {metric} does not support {} points",
                    sample_points.kind()
                )));
            }

            // Fit the inner algorithm on the resident sample.
            let t_fit = Timer::start();
            let mut algo: Box<dyn KMedoids> = self.inner.make_algo()?;
            let mut sample_backend = NativeBackend::new(&sample_points, metric);
            if let Some(p) = &pool {
                sample_backend = sample_backend.with_pool(p.clone());
            }
            if let Some(entries) = self.inner.cache {
                sample_backend = sample_backend.with_cache(entries);
            }
            let inner_fit = algo.fit(&sample_backend, k, &mut rng)?;
            drop(sample_backend);
            build_evals += inner_fit.stats.distance_evals;
            swap_iters += inner_fit.stats.swap_iters;
            swaps_applied += inner_fit.stats.swaps_applied;
            let fit_secs = t_fit.secs();

            // Map sample-local medoids to sorted global indices, keeping
            // the local positions aligned so the extracted rows land in
            // the same (ascending-global) order the assignments index.
            let mut pairs: Vec<(usize, usize)> =
                inner_fit.medoids.iter().map(|&loc| (idx[loc], loc)).collect();
            pairs.sort_unstable();
            let medoids: Vec<usize> = pairs.iter().map(|&(g, _)| g).collect();
            let locals: Vec<usize> = pairs.iter().map(|&(_, l)| l).collect();
            let medoid_points = sample_points.select(&locals);
            // Residency drops to medoids + one window from here on.
            drop(sample_points);

            // Score the candidate over the full dataset, window by window.
            let t_eval = Timer::start();
            let mut medoid_backend = NativeBackend::new(&medoid_points, metric);
            if let Some(p) = &pool {
                medoid_backend = medoid_backend.with_pool(p.clone());
            }
            let (loss, assignments) = src.eval(&medoid_backend, nnz_of(&medoid_points))?;
            eval_evals += medoid_backend.counter().get();
            let eval_secs = t_eval.secs();

            trajectory.push(SampleTrace { sample, loss, subsample_secs, fit_secs, eval_secs });
            if let Some(sink) = &self.inner.trace {
                sink.emit(
                    "bigfit_sample",
                    &[
                        ("sample", TraceValue::from(sample)),
                        ("sample_size", TraceValue::from(ssize)),
                        ("loss", TraceValue::from(loss)),
                        ("subsample_secs", TraceValue::from(subsample_secs)),
                        ("fit_secs", TraceValue::from(fit_secs)),
                        ("eval_secs", TraceValue::from(eval_secs)),
                        ("eval_rows_per_sec", TraceValue::from(n as f64 / eval_secs)),
                    ],
                );
            }
            if best.as_ref().map(|(l, _, _, _)| loss < *l).unwrap_or(true) {
                best = Some((loss, medoids, assignments, medoid_points));
            }
        }

        let (loss, medoids, assignments, medoid_points) = best.unwrap();
        let mut stats = FitStats {
            build_evals,
            eval_evals,
            samples: self.samples,
            swap_iters,
            swaps_applied,
            iters_plus_one: swap_iters + 1,
            wall_secs: total.secs(),
            ..Default::default()
        };
        stats.distance_evals = stats.build_evals + stats.swap_evals + stats.eval_evals;
        let clustering = Clustering { medoids, assignments, loss, stats };
        let model = KMedoidsModel::from_extracted(
            medoid_points,
            metric,
            clustering,
            n,
            format!("bigfit+{}", self.inner.algorithm),
            self.fingerprint(),
        )?
        .with_threads(threads);
        let big_stats = BigFitStats {
            samples: self.samples,
            sample_size: ssize,
            n_rows: n,
            total_nnz: src.total_nnz(),
            peak_window_nnz: src.peak_window_nnz(),
            peak_resident_nnz: src.peak_resident_nnz(),
            trajectory,
            wall_secs: total.secs(),
        };
        if let Some(sink) = &self.inner.trace {
            sink.emit(
                "bigfit_summary",
                &[
                    ("samples", TraceValue::from(self.samples)),
                    ("sample_size", TraceValue::from(ssize)),
                    ("n_rows", TraceValue::from(n)),
                    ("loss", TraceValue::from(loss)),
                    ("total_nnz", TraceValue::from(big_stats.total_nnz)),
                    ("peak_window_nnz", TraceValue::from(big_stats.peak_window_nnz)),
                    ("peak_resident_nnz", TraceValue::from(big_stats.peak_resident_nnz)),
                    ("wall_secs", TraceValue::from(big_stats.wall_secs)),
                ],
            );
            let _ = sink.flush();
        }
        Ok((model, big_stats))
    }

    /// Reproducibility fingerprint: the outer-loop knobs plus the inner
    /// fit's own fingerprint.
    fn fingerprint(&self) -> String {
        format!(
            "bigfit samples={} sample_size={} inner[{}]",
            self.samples,
            self.sample_size,
            self.inner.fingerprint()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::distance::Metric;

    #[test]
    fn bigfit_returns_valid_model_and_honest_stats() {
        let ds = synthetic::gmm(&mut Rng::seed_from(60), 300, 6, 3, 4.0);
        let (model, stats) = Fit::pam()
            .metric(Metric::L2)
            .k(3)
            .seed(9)
            .big()
            .samples(3)
            .fit_with_stats(&ds)
            .unwrap();
        assert_eq!(model.k(), 3);
        assert_eq!(model.n_train(), 300);
        assert_eq!(model.algorithm(), "bigfit+pam");
        assert!(model.config_fingerprint().starts_with("bigfit samples=3"));
        assert_eq!(model.clustering().assignments.len(), 300);
        // every candidate scored k*n once; no hidden winner re-evaluation
        let fs = &model.clustering().stats;
        assert_eq!(fs.eval_evals, (3 * 3 * 300) as u64);
        assert_eq!(fs.samples, 3);
        assert_eq!(fs.distance_evals, fs.build_evals + fs.eval_evals);
        assert_eq!(stats.samples, 3);
        assert_eq!(stats.sample_size, 40 + 2 * 3);
        assert_eq!(stats.n_rows, 300);
        assert_eq!(stats.trajectory.len(), 3);
        let best = stats.trajectory.iter().map(|t| t.loss).fold(f64::INFINITY, f64::min);
        assert_eq!(model.loss().to_bits(), best.to_bits());
    }

    /// The model predicts its own training set back to the stored
    /// assignments — the from_extracted path preserves the predict
    /// contract end to end.
    #[test]
    fn bigfit_model_predicts_training_set_bitwise() {
        let ds = synthetic::gmm(&mut Rng::seed_from(61), 250, 5, 4, 3.5);
        let model =
            Fit::fastpam1().k(4).seed(12).big().samples(2).fit(&ds).unwrap();
        let pred = model.predict(&ds.points).unwrap();
        assert_eq!(&pred, &model.clustering().assignments);
    }

    /// ISSUE 9: the outer loop composes with the OneBatchPAM arm (the
    /// per-sample inner fit draws its own batch from the sample) and the
    /// result round-trips through the byte format with predict intact.
    #[test]
    fn bigfit_onebatchpam_round_trips_bytes_and_predict() {
        let ds = synthetic::gmm(&mut Rng::seed_from(64), 260, 5, 3, 3.5);
        let model =
            Fit::onebatchpam().k(3).seed(8).big().samples(2).fit(&ds).unwrap();
        assert_eq!(model.algorithm(), "bigfit+onebatchpam");
        let bytes = model.to_bytes().unwrap();
        let back = KMedoidsModel::from_bytes(&bytes).unwrap();
        assert_eq!(back.algorithm(), "bigfit+onebatchpam");
        assert_eq!(back.clustering().medoids, model.clustering().medoids);
        assert_eq!(back.loss().to_bits(), model.loss().to_bits());
        let pred = back.predict(&ds.points).unwrap();
        assert_eq!(&pred, &model.clustering().assignments);
    }

    #[test]
    fn bigfit_thread_count_never_changes_bits() {
        let ds = synthetic::gmm(&mut Rng::seed_from(62), 220, 6, 3, 4.0);
        let one = Fit::pam().k(3).seed(5).big().samples(2).fit(&ds).unwrap();
        let many =
            Fit::pam().k(3).seed(5).threads(4).big().samples(2).fit(&ds).unwrap();
        assert_eq!(one.clustering().medoids, many.clustering().medoids);
        assert_eq!(one.clustering().assignments, many.clustering().assignments);
        assert_eq!(one.loss().to_bits(), many.loss().to_bits());
    }

    #[test]
    fn bigfit_rejects_bad_arguments() {
        let ds = synthetic::gmm(&mut Rng::seed_from(63), 40, 4, 2, 3.0);
        let err = Fit::pam().k(2).big().samples(0).fit(&ds).unwrap_err();
        assert_eq!(err.kind(), "invalid_argument");
        // sample_size <= k
        let err = Fit::pam().k(5).big().sample_size(5).fit(&ds).unwrap_err();
        assert_eq!(err.kind(), "invalid_argument");
        // empty dataset
        let empty = crate::data::Dataset {
            points: Points::Dense(crate::util::matrix::Matrix::zeros(0, 4)),
            labels: None,
            name: "empty".into(),
        };
        let err = Fit::pam().k(2).big().fit(&empty).unwrap_err();
        assert_eq!(err.kind(), "invalid_argument");
    }
}
