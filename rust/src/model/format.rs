//! The versioned little-endian binary model format.
//!
//! Layout (all integers little-endian; full specification with the
//! rationale in `rust/MODEL.md`):
//!
//! ```text
//! magic        8  b"BPAMMODL"
//! version      4  u32 = 1
//! metric       1  u8: 0 = l2, 1 = l1, 2 = cosine
//! storage      1  u8: 0 = dense, 1 = sparse
//! reserved     2  u16 = 0
//! k            4  u32 (>= 1)
//! dim          4  u32
//! n_train      4  u32 (>= k)
//! loss         8  f64
//! stats       64  u64 x7 (distance/build/swap/swap-saved evals,
//!                 swap_iters, swaps_applied, iters_plus_one) + f64 wall_secs
//! algorithm    4 + len  (u32 length + UTF-8)
//! fingerprint  4 + len  (u32 length + UTF-8)
//! medoids      4k  u32 training indices, strictly increasing, < n_train
//! assignments  4*n_train  u32, < k
//! payload      dense:  k*dim x f32 (row-major medoid rows)
//!              sparse: u64 nnz; (k+1) x u64 indptr; nnz x u32 indices;
//!                      nnz x f32 values  (CSR invariants enforced)
//! ```
//!
//! The reader is hardened against hostile input in the
//! `tests/stream_fixtures.rs` style: every length is checked against the
//! bytes actually present *before* any allocation (a lying header cannot
//! force an OOM), every invariant violation is a clean
//! [`Error::Model`](crate::error::Error::Model), and trailing bytes are
//! rejected. Tree-medoid models have no serialized form.

use super::KMedoidsModel;
use crate::algorithms::{Clustering, FitStats};
use crate::data::sparse::CsrMatrix;
use crate::data::Points;
use crate::distance::Metric;
use crate::error::{Error, Result};
use crate::util::matrix::Matrix;

pub(super) const MAGIC: &[u8; 8] = b"BPAMMODL";
pub(super) const VERSION: u32 = 1;
/// Cap on the algorithm/fingerprint string lengths — far above anything
/// the crate writes, low enough that a lying length cannot hurt.
const MAX_STRING: usize = 1 << 16;

fn metric_tag(m: Metric) -> Option<u8> {
    match m {
        Metric::L2 => Some(0),
        Metric::L1 => Some(1),
        Metric::Cosine => Some(2),
        Metric::TreeEdit => None,
    }
}

fn tag_metric(t: u8) -> Option<Metric> {
    match t {
        0 => Some(Metric::L2),
        1 => Some(Metric::L1),
        2 => Some(Metric::Cosine),
        _ => None,
    }
}

fn fits_u32(what: &str, v: usize) -> Result<u32> {
    u32::try_from(v).map_err(|_| Error::model(format!("{what} {v} exceeds the u32 format field")))
}

pub(super) fn write(model: &KMedoidsModel) -> Result<Vec<u8>> {
    let metric = metric_tag(model.metric).ok_or_else(|| {
        Error::unsupported("tree-edit models have no serialized form (medoids are ASTs)")
    })?;
    let (storage, dim) = match &model.medoid_points {
        Points::Dense(m) => (0u8, m.cols()),
        Points::Sparse(m) => (1u8, m.cols()),
        Points::Trees(_) => {
            return Err(Error::unsupported(
                "tree-medoid models have no serialized form",
            ))
        }
    };
    let c = &model.clustering;
    let k = fits_u32("k", c.medoids.len())?;
    let dim = fits_u32("dim", dim)?;
    let n_train = fits_u32("n_train", model.n_train)?;
    if model.algorithm.len() > MAX_STRING || model.fingerprint.len() > MAX_STRING {
        return Err(Error::model("metadata string exceeds the format cap"));
    }

    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(metric);
    out.push(storage);
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&k.to_le_bytes());
    out.extend_from_slice(&dim.to_le_bytes());
    out.extend_from_slice(&n_train.to_le_bytes());
    out.extend_from_slice(&c.loss.to_le_bytes());
    for v in [
        c.stats.distance_evals,
        c.stats.build_evals,
        c.stats.swap_evals,
        c.stats.swap_evals_saved,
        c.stats.swap_iters as u64,
        c.stats.swaps_applied as u64,
        c.stats.iters_plus_one as u64,
    ] {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out.extend_from_slice(&c.stats.wall_secs.to_le_bytes());
    for s in [&model.algorithm, &model.fingerprint] {
        out.extend_from_slice(&(s.len() as u32).to_le_bytes());
        out.extend_from_slice(s.as_bytes());
    }
    for &m in &c.medoids {
        out.extend_from_slice(&fits_u32("medoid index", m)?.to_le_bytes());
    }
    for &a in &c.assignments {
        out.extend_from_slice(&fits_u32("assignment", a)?.to_le_bytes());
    }
    match &model.medoid_points {
        Points::Dense(m) => {
            for i in 0..m.rows() {
                for &v in m.row(i) {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        Points::Sparse(m) => {
            let (indptr, indices, values) = m.parts();
            out.extend_from_slice(&(indices.len() as u64).to_le_bytes());
            for &p in indptr {
                out.extend_from_slice(&(p as u64).to_le_bytes());
            }
            for &j in indices {
                out.extend_from_slice(&j.to_le_bytes());
            }
            for &v in values {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Points::Trees(_) => unreachable!("rejected above"),
    }
    Ok(out)
}

/// Bounds-checked little-endian cursor. Every read names what it was
/// reading, so a truncation error pinpoints the failing field.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::model(format!(
                "truncated model file: need {n} bytes for {what}, {} left",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    /// A `count`-element vector of fixed-size scalars, length-checked
    /// against the remaining bytes *before* allocating.
    fn vec<T>(
        &mut self,
        count: usize,
        size: usize,
        what: &str,
        decode: impl Fn(&[u8]) -> T,
    ) -> Result<Vec<T>> {
        let bytes = count
            .checked_mul(size)
            .ok_or_else(|| Error::model(format!("{what} count {count} overflows")))?;
        let raw = self.take(bytes, what)?;
        Ok(raw.chunks_exact(size).map(decode).collect())
    }

    fn string(&mut self, what: &str) -> Result<String> {
        let len = self.u32(what)? as usize;
        if len > MAX_STRING {
            return Err(Error::model(format!(
                "{what} length {len} exceeds the format cap {MAX_STRING}"
            )));
        }
        let raw = self.take(len, what)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| Error::model(format!("{what} is not valid UTF-8")))
    }
}

pub(super) fn read(bytes: &[u8]) -> Result<KMedoidsModel> {
    let mut r = Reader::new(bytes);
    if r.take(8, "magic")? != MAGIC {
        return Err(Error::model("not a banditpam model file (bad magic)"));
    }
    let version = r.u32("version")?;
    if version != VERSION {
        return Err(Error::model(format!(
            "unsupported model format version {version} (expected {VERSION})"
        )));
    }
    let metric = tag_metric(r.u8("metric tag")?)
        .ok_or_else(|| Error::model("unknown metric tag"))?;
    let storage = r.u8("storage tag")?;
    if storage > 1 {
        return Err(Error::model(format!("unknown storage tag {storage}")));
    }
    if r.u16("reserved")? != 0 {
        return Err(Error::model("reserved field must be zero"));
    }
    let k = r.u32("k")? as usize;
    let dim = r.u32("dim")? as usize;
    let n_train = r.u32("n_train")? as usize;
    if k == 0 {
        return Err(Error::model("k must be >= 1"));
    }
    if n_train < k {
        return Err(Error::model(format!("n_train {n_train} smaller than k {k}")));
    }
    let loss = r.f64("loss")?;
    // `eval_evals`/`samples` are not serialized (format v1 predates the
    // sampling outer loops); they reload as 0.
    let stats = FitStats {
        distance_evals: r.u64("distance_evals")?,
        build_evals: r.u64("build_evals")?,
        swap_evals: r.u64("swap_evals")?,
        swap_evals_saved: r.u64("swap_evals_saved")?,
        swap_iters: r.u64("swap_iters")? as usize,
        swaps_applied: r.u64("swaps_applied")? as usize,
        iters_plus_one: r.u64("iters_plus_one")? as usize,
        wall_secs: r.f64("wall_secs")?,
        ..Default::default()
    };
    let algorithm = r.string("algorithm name")?;
    let fingerprint = r.string("config fingerprint")?;
    let medoids: Vec<usize> = r.vec(k, 4, "medoid indices", |b| {
        u32::from_le_bytes(b.try_into().unwrap()) as usize
    })?;
    if let Some(&bad) = medoids.iter().find(|&&m| m >= n_train) {
        return Err(Error::model(format!(
            "medoid index {bad} out of range for n_train {n_train}"
        )));
    }
    if medoids.windows(2).any(|w| w[0] >= w[1]) {
        return Err(Error::model("medoid indices must be strictly increasing"));
    }
    let assignments: Vec<usize> = r.vec(n_train, 4, "assignments", |b| {
        u32::from_le_bytes(b.try_into().unwrap()) as usize
    })?;
    if let Some(&bad) = assignments.iter().find(|&&a| a >= k) {
        return Err(Error::model(format!("assignment {bad} out of range for k {k}")));
    }
    let medoid_points = if storage == 0 {
        let count = k
            .checked_mul(dim)
            .ok_or_else(|| Error::model("k * dim overflows"))?;
        let data = r.vec(count, 4, "dense medoid payload", |b| {
            f32::from_le_bytes(b.try_into().unwrap())
        })?;
        // A NaN medoid coordinate corrupts every assignment argmin; the
        // sparse branch gets the same guarantee from `try_from_parts`.
        if let Some(v) = data.iter().find(|v| !v.is_finite()) {
            return Err(Error::model(format!(
                "non-finite value {v} in the dense medoid payload"
            )));
        }
        Points::Dense(Matrix::from_vec(data, k, dim))
    } else {
        let nnz = usize::try_from(r.u64("nnz")?)
            .map_err(|_| Error::model("nnz exceeds the address space"))?;
        let indptr: Vec<usize> = r
            .vec(k + 1, 8, "indptr", |b| u64::from_le_bytes(b.try_into().unwrap()))?
            .into_iter()
            .map(|p| {
                usize::try_from(p).map_err(|_| Error::model("indptr entry overflows"))
            })
            .collect::<Result<_>>()?;
        let indices: Vec<u32> =
            r.vec(nnz, 4, "column indices", |b| u32::from_le_bytes(b.try_into().unwrap()))?;
        let values: Vec<f32> =
            r.vec(nnz, 4, "values", |b| f32::from_le_bytes(b.try_into().unwrap()))?;
        let csr = CsrMatrix::try_from_parts(k, dim, indptr, indices, values)
            .map_err(|e| Error::model(format!("corrupt CSR payload: {e}")))?;
        Points::Sparse(csr)
    };
    if r.remaining() != 0 {
        return Err(Error::model(format!(
            "{} trailing bytes after the payload",
            r.remaining()
        )));
    }
    Ok(KMedoidsModel {
        medoid_points,
        metric,
        clustering: Clustering { medoids, assignments, loss, stats },
        algorithm,
        fingerprint,
        n_train,
        threads: 1,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_tags_roundtrip() {
        for m in [Metric::L2, Metric::L1, Metric::Cosine] {
            assert_eq!(tag_metric(metric_tag(m).unwrap()), Some(m));
        }
        assert_eq!(metric_tag(Metric::TreeEdit), None);
        assert_eq!(tag_metric(3), None);
    }

    #[test]
    fn reader_reports_truncation_with_field_names() {
        let mut r = Reader::new(&[1, 2, 3]);
        let err = r.u32("version").unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn reader_rejects_overflowing_vec_before_allocating() {
        let mut r = Reader::new(&[0u8; 16]);
        let err = r
            .vec(usize::MAX, 8, "indptr", |b| u64::from_le_bytes(b.try_into().unwrap()))
            .unwrap_err();
        assert!(err.to_string().contains("indptr"), "{err}");
    }

    /// Both payload branches reject NaN medoid coordinates: the file ends
    /// with the payload values, so patching the final 4 bytes corrupts
    /// exactly one stored f32.
    #[test]
    fn read_rejects_non_finite_payload_values() {
        use crate::data::synthetic;
        use crate::util::rng::Rng;
        let dense = synthetic::gmm(&mut Rng::seed_from(5), 20, 6, 2, 3.0);
        let sparse = synthetic::scrna_like(&mut Rng::seed_from(6), 20, 32)
            .to_sparse()
            .unwrap();
        for ds in [dense, sparse] {
            let model = super::super::Fit::banditpam()
                .metric(Metric::L1)
                .seed(3)
                .k(2)
                .fit(&ds)
                .unwrap();
            let mut bytes = model.to_bytes().unwrap();
            assert!(read(&bytes).is_ok());
            let n = bytes.len();
            bytes[n - 4..].copy_from_slice(&f32::NAN.to_le_bytes());
            let err = read(&bytes).unwrap_err();
            assert_eq!(err.kind(), "model", "{}", ds.points.kind());
            assert!(err.message().contains("non-finite"), "{err}");
        }
    }
}
