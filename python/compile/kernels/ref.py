"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every Pallas kernel in :mod:`pairwise` and every fused graph in
``compile.model`` is checked against these references by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes and contents).
The Rust `NativeBackend` mirrors the same definitions, so the oracle also
pins the cross-language contract.
"""

from __future__ import annotations

import jax.numpy as jnp


def l2_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """``out[i, j] = ||x[i] - y[j]||_2`` via explicit broadcast."""
    diff = x[:, None, :] - y[None, :, :]
    return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))


def l1_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """``out[i, j] = ||x[i] - y[j]||_1``."""
    return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)


def cosine_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """``out[i, j] = 1 - cos(x[i], y[j])``; zero vectors get distance 1."""
    dot = x @ y.T
    xn = jnp.sqrt(jnp.sum(x * x, axis=1))
    yn = jnp.sqrt(jnp.sum(y * y, axis=1))
    denom = xn[:, None] * yn[None, :]
    cos = jnp.where(denom > 0.0, dot / jnp.where(denom > 0.0, denom, 1.0), 0.0)
    return 1.0 - cos


def build_g_ref(
    x: jnp.ndarray, y: jnp.ndarray, dnear: jnp.ndarray, w: jnp.ndarray
) -> jnp.ndarray:
    """Fused BUILD-step arm pull (Eq. 9 of the paper), l2 metric.

    ``g_x(x_j) = (d(x, x_j) - dnear_j) ^ 0``; returns the weighted mean over
    the reference batch for each target: ``out[i] = sum_j w_j g / sum_j w_j``.
    ``w`` masks padded reference rows.
    """
    d = l2_ref(x, y)
    g = jnp.minimum(d - dnear[None, :], 0.0)
    return (g * w[None, :]).sum(axis=1) / jnp.maximum(w.sum(), 1.0)


REF = {"l2": l2_ref, "l1": l1_ref, "cosine": cosine_ref}
