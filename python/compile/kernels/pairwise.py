"""Layer-1 Pallas kernels: tiled pairwise-distance blocks.

These are the compute hot spot of BanditPAM: every arm pull in
Algorithm 1 evaluates distances between a set of live target points and a
common reference batch, i.e. a dense ``[T, R]`` pairwise-distance block.

TPU mapping (see DESIGN.md "Hardware-Adaptation"):

* ``l2`` / ``cosine`` reduce to a single ``[T, D] x [D, R]`` matmul (the MXU
  systolic array's native shape) plus per-row norm vectors that are computed
  once per block on the VPU: ``d^2 = |x|^2 + |y|^2 - 2 x.y``.
* ``l1`` has no matmul form; its kernel tiles the D axis and accumulates
  ``sum |x_i - y_i|`` into the VMEM-resident output tile (VPU-bound).

All kernels share one BlockSpec schedule: grid ``(T/Tb, R/Rb, D/Db)`` with
the D axis innermost so the ``[Tb, Rb]`` output tile stays resident in VMEM
while HBM streams the x/y stripes -- the Pallas analogue of the threadblock
tiling a CUDA kernel would use for the same computation.

Kernels are executed with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so the kernels lower to plain HLO that both the pytest
oracle checks and the Rust runtime execute. Real-TPU performance is budgeted
statically in DESIGN.md / EXPERIMENTS.md "Perf".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile shapes. Tb*Db and Rb*Db stripes plus the [Tb, Rb] out tile
# must fit (double-buffered) in ~16 MiB VMEM; see DESIGN.md for the budget.
DEFAULT_TB = 64
DEFAULT_RB = 128
DEFAULT_DB = 128


def _check_tiles(t: int, r: int, d: int, tb: int, rb: int, db: int) -> None:
    if t % tb or r % rb or d % db:
        raise ValueError(
            f"shape ({t},{r},{d}) not divisible by tiles ({tb},{rb},{db}); "
            "pad inputs first (the Rust runtime pads to artifact shapes)"
        )


def fit_tile(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is <= ``pref`` (tile auto-fitting)."""
    pref = min(pref, dim)
    for cand in range(pref, 0, -1):
        if dim % cand == 0:
            return cand
    return 1


# ---------------------------------------------------------------------------
# l2: d(x, y) = sqrt(max(|x|^2 + |y|^2 - 2 x.y, 0))
# ---------------------------------------------------------------------------


def _l2_kernel(x_ref, y_ref, xsq_ref, ysq_ref, o_ref):
    """Accumulate -2 * x @ y.T over D tiles; finalize with norms + sqrt."""
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU: [Tb, Db] x [Db, Rb] partial cross term.
    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...].T, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finalize():
        dot = o_ref[...]
        sq = xsq_ref[...].reshape(-1, 1) + ysq_ref[...].reshape(1, -1) - 2.0 * dot
        o_ref[...] = jnp.sqrt(jnp.maximum(sq, 0.0))


def l2_pairwise(
    x: jax.Array,
    y: jax.Array,
    *,
    tb: int = DEFAULT_TB,
    rb: int = DEFAULT_RB,
    db: int = DEFAULT_DB,
) -> jax.Array:
    """Euclidean distance block: ``out[i, j] = ||x[i] - y[j]||_2``.

    ``x: [T, D]``, ``y: [R, D]`` -> ``[T, R]`` (all float32).
    """
    t, d = x.shape
    r, d2 = y.shape
    assert d == d2, (d, d2)
    tb, rb, db = fit_tile(t, tb), fit_tile(r, rb), fit_tile(d, db)
    _check_tiles(t, r, d, tb, rb, db)
    # Squared norms are O(ND) VPU work, computed once outside the grid so the
    # kernel's accumulator holds only the matmul cross term.
    xsq = jnp.sum(x * x, axis=1)
    ysq = jnp.sum(y * y, axis=1)
    return pl.pallas_call(
        _l2_kernel,
        grid=(t // tb, r // rb, d // db),
        in_specs=[
            pl.BlockSpec((tb, db), lambda i, j, k: (i, k)),
            pl.BlockSpec((rb, db), lambda i, j, k: (j, k)),
            pl.BlockSpec((tb,), lambda i, j, k: (i,)),
            pl.BlockSpec((rb,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((tb, rb), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, r), jnp.float32),
        interpret=True,
    )(x, y, xsq, ysq)


# ---------------------------------------------------------------------------
# cosine: d(x, y) = 1 - x.y / (|x| |y|)
# ---------------------------------------------------------------------------


def _cosine_kernel(x_ref, y_ref, xn_ref, yn_ref, o_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...].T, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finalize():
        denom = xn_ref[...].reshape(-1, 1) * yn_ref[...].reshape(1, -1)
        # Zero vectors get distance 1 (cos sim 0), matching ref.py / Rust.
        safe = jnp.where(denom > 0.0, denom, 1.0)
        cos = jnp.where(denom > 0.0, o_ref[...] / safe, 0.0)
        o_ref[...] = 1.0 - cos


def cosine_pairwise(
    x: jax.Array,
    y: jax.Array,
    *,
    tb: int = DEFAULT_TB,
    rb: int = DEFAULT_RB,
    db: int = DEFAULT_DB,
) -> jax.Array:
    """Cosine distance block: ``out[i, j] = 1 - cos(x[i], y[j])``."""
    t, d = x.shape
    r, d2 = y.shape
    assert d == d2, (d, d2)
    tb, rb, db = fit_tile(t, tb), fit_tile(r, rb), fit_tile(d, db)
    _check_tiles(t, r, d, tb, rb, db)
    xn = jnp.sqrt(jnp.sum(x * x, axis=1))
    yn = jnp.sqrt(jnp.sum(y * y, axis=1))
    return pl.pallas_call(
        _cosine_kernel,
        grid=(t // tb, r // rb, d // db),
        in_specs=[
            pl.BlockSpec((tb, db), lambda i, j, k: (i, k)),
            pl.BlockSpec((rb, db), lambda i, j, k: (j, k)),
            pl.BlockSpec((tb,), lambda i, j, k: (i,)),
            pl.BlockSpec((rb,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((tb, rb), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, r), jnp.float32),
        interpret=True,
    )(x, y, xn, yn)


# ---------------------------------------------------------------------------
# l1: d(x, y) = sum_i |x_i - y_i|   (VPU-bound; no matmul form)
# ---------------------------------------------------------------------------


def _l1_kernel(x_ref, y_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Broadcasted [Tb, Rb, Db] diff lives only for this tile; Db bounds the
    # VMEM spike (Tb*Rb*Db*4 bytes).
    diff = x_ref[...][:, None, :] - y_ref[...][None, :, :]
    o_ref[...] += jnp.sum(jnp.abs(diff), axis=-1)


def l1_pairwise(
    x: jax.Array,
    y: jax.Array,
    *,
    tb: int = DEFAULT_TB,
    rb: int = DEFAULT_RB,
    db: int = 32,
) -> jax.Array:
    """Manhattan distance block: ``out[i, j] = ||x[i] - y[j]||_1``."""
    t, d = x.shape
    r, d2 = y.shape
    assert d == d2, (d, d2)
    tb, rb, db = fit_tile(t, tb), fit_tile(r, rb), fit_tile(d, db)
    _check_tiles(t, r, d, tb, rb, db)
    return pl.pallas_call(
        _l1_kernel,
        grid=(t // tb, r // rb, d // db),
        in_specs=[
            pl.BlockSpec((tb, db), lambda i, j, k: (i, k)),
            pl.BlockSpec((rb, db), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tb, rb), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, r), jnp.float32),
        interpret=True,
    )(x, y)


PAIRWISE = {
    "l2": l2_pairwise,
    "l1": l1_pairwise,
    "cosine": cosine_pairwise,
}


@functools.lru_cache(maxsize=None)
def get_kernel(metric: str):
    """Look up a pairwise kernel by metric name (raises on unknown)."""
    try:
        return PAIRWISE[metric]
    except KeyError:
        raise ValueError(f"unknown metric {metric!r}; have {sorted(PAIRWISE)}")
