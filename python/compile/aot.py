"""AOT lowering: jax/Pallas graphs -> HLO *text* artifacts + manifest.

This is the only place Python touches the system; it runs at build time
(``make artifacts``) and never on the Rust request path.

Interchange format is HLO **text**, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
bundled XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Outputs (under ``artifacts/``):

* ``<name>.hlo.txt``  -- one per entry in ``CONFIGS``
* ``manifest.json``   -- schema the Rust runtime reads: for each artifact its
  graph kind, metric, tile shape (t, r, d[, k]), and file name.

Usage: ``python -m compile.aot --out ../artifacts`` (idempotent: skips
regeneration when the sources are older than the manifest).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax

from . import model

# ---------------------------------------------------------------------------
# Artifact configurations.
#
# Tile shapes are fixed here and padded up to by the Rust XlaBackend. R=128
# holds the paper's reference batch size B=100 with masking; D covers the
# dataset families we ship (16: PCA/quickstart, 64: generic, 784: MNIST-like).
# ---------------------------------------------------------------------------

CONFIGS = []
for _metric in ("l2", "l1", "cosine"):
    for _d in (16, 64, 784):
        CONFIGS.append(
            {
                "kind": "pairwise",
                "metric": _metric,
                "t": 64,
                "r": 128,
                "d": _d,
                "name": f"pairwise_{_metric}_64x128x{_d}",
            }
        )
CONFIGS.append(
    {"kind": "build_g", "metric": "l2", "t": 64, "r": 128, "d": 784,
     "name": "build_g_l2_64x128x784"}
)
CONFIGS.append(
    {"kind": "swap_delta", "metric": "l2", "t": 64, "r": 128, "d": 784, "k": 8,
     "name": "swap_delta_l2_64x128x784x8"}
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_config(cfg: dict) -> str:
    shapes = model.example_shapes(cfg["t"], cfg["r"], cfg["d"], cfg.get("k", 8))
    if cfg["kind"] == "pairwise":
        fn = model.pairwise(cfg["metric"])
        args = shapes["pairwise"]
    elif cfg["kind"] == "build_g":
        fn = model.build_g_mean
        args = shapes["build_g"]
    elif cfg["kind"] == "swap_delta":
        fn = model.swap_delta
        args = shapes["swap_delta"]
    else:
        raise ValueError(f"unknown artifact kind {cfg['kind']!r}")
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def newest_source_mtime() -> float:
    here = os.path.dirname(os.path.abspath(__file__))
    paths = [os.path.join(here, "aot.py"), os.path.join(here, "model.py")]
    kdir = os.path.join(here, "kernels")
    paths += [os.path.join(kdir, f) for f in os.listdir(kdir) if f.endswith(".py")]
    return max(os.path.getmtime(p) for p in paths)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--force", action="store_true", help="regenerate even if fresh")
    ap.add_argument(
        "--only", default=None, help="comma-separated artifact names to build"
    )
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")

    if not args.force and os.path.exists(manifest_path):
        if os.path.getmtime(manifest_path) >= newest_source_mtime():
            print(f"artifacts fresh ({manifest_path}); nothing to do")
            return 0

    only = set(args.only.split(",")) if args.only else None
    entries = []
    for cfg in CONFIGS:
        if only and cfg["name"] not in only:
            continue
        text = lower_config(cfg)
        fname = f"{cfg['name']}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        entry = dict(cfg)
        entry["file"] = fname
        entries.append(entry)
        print(f"lowered {cfg['name']:<36} -> {fname} ({len(text)} chars)")

    with open(manifest_path, "w") as f:
        json.dump({"version": 1, "artifacts": entries}, f, indent=2)
    print(f"wrote {manifest_path} ({len(entries)} artifacts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
