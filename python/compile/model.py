"""Layer-2 JAX compute graphs for BanditPAM's arm pulls.

BanditPAM's only heavy computation is evaluating distance blocks between
live arms (targets) and sampled reference batches.  This module wraps the
Layer-1 Pallas kernels (``kernels.pairwise``) into the jittable functions
that ``aot.py`` lowers to HLO text for the Rust runtime:

* ``pairwise(metric)``        -> ``f(x[T,D], y[R,D]) -> d[T,R]``
* ``build_g_mean``            -> the fused BUILD-step arm pull (Eq. 9):
  ``f(x[T,D], y[R,D], dnear[R], w[R]) -> g_mean[T]`` where
  ``g = min(d(x, x_j) - dnear_j, 0)`` and ``w`` masks padded rows.
* ``swap_delta``              -> the fused FastPAM1 SWAP pull (Eq. 12
  rearranged): given the candidate-x distance row and the cached
  ``d1``/``d2``/membership mask, the per-(m, x) loss delta.

The min/mean epilogues are plain jnp around the Pallas call -- XLA fuses
them into the kernel's consumer, so the whole arm pull is one executable.

Shapes are fixed at lowering time (AOT); the Rust ``XlaBackend`` pads
requests up to the artifact shape and masks the padding out.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import pairwise as pk


def pairwise(metric: str):
    """Return the jittable pairwise-distance graph for ``metric``."""
    kernel = pk.get_kernel(metric)

    def fn(x, y):
        return (kernel(x, y),)

    fn.__name__ = f"pairwise_{metric}"
    return fn


def build_g_mean(x, y, dnear, w):
    """Fused BUILD arm pull: weighted mean of ``min(d - dnear, 0)`` per target.

    ``x: [T, D]`` live BUILD arms, ``y: [R, D]`` reference batch,
    ``dnear: [R]`` cached distance from each reference to its nearest
    current medoid (+inf when no medoids yet), ``w: [R]`` 0/1 padding mask.
    Returns ``([T],)``.
    """
    d = pk.l2_pairwise(x, y)
    g = jnp.minimum(d - dnear[None, :], 0.0)
    denom = jnp.maximum(jnp.sum(w), 1.0)
    return ((g * w[None, :]).sum(axis=1) / denom,)


def swap_delta(x, y, d1, d2, near_is_m, w):
    """Fused SWAP arm pull with the FastPAM1 decomposition (Eq. 12).

    For a block of candidate points ``x: [T, D]`` and reference batch
    ``y: [R, D]`` with cached ``d1, d2: [R]`` (nearest / second-nearest
    medoid distances) and ``near_is_m: [K, R]`` (1 when reference j's nearest
    medoid is medoid m), returns the weighted-mean loss delta for every
    (medoid m, candidate x) pair: ``([K, T],)``.

        g_{m,x}(j) = -d1_j + [j not in C_m] min(d1_j, d(x, j))
                           + [j     in C_m] min(d2_j, d(x, j))
    """
    d = pk.l2_pairwise(x, y)  # [T, R]
    min1 = jnp.minimum(d, d1[None, :])  # [T, R]
    min2 = jnp.minimum(d, d2[None, :])  # [T, R]
    # delta[k, t, j] = -d1_j + (1 - near_is_m[k, j]) * min1[t, j]
    #                        + near_is_m[k, j] * min2[t, j]
    contrib = min1[None, :, :] + near_is_m[:, None, :] * (min2 - min1)[None, :, :]
    g = contrib - d1[None, None, :]
    denom = jnp.maximum(jnp.sum(w), 1.0)
    return ((g * w[None, None, :]).sum(axis=-1) / denom,)


def example_shapes(t: int, r: int, d: int, k: int = 8):
    """ShapeDtypeStructs for lowering each graph at a given tile config."""
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return {
        "pairwise": (s((t, d), f32), s((r, d), f32)),
        "build_g": (s((t, d), f32), s((r, d), f32), s((r,), f32), s((r,), f32)),
        "swap_delta": (
            s((t, d), f32),
            s((r, d), f32),
            s((r,), f32),
            s((r,), f32),
            s((k, r), f32),
            s((r,), f32),
        ),
    }
