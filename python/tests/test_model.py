"""Layer-2 graph tests: fused arm pulls vs explicit references + lowering."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

TOL = dict(rtol=2e-4, atol=2e-4)


def _data(t=8, r=12, d=16, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((t, d)).astype(np.float32)
    y = rng.standard_normal((r, d)).astype(np.float32)
    return x, y


# ---------------------------------------------------------------------------
# build_g_mean
# ---------------------------------------------------------------------------


def test_build_g_matches_ref():
    x, y = _data(seed=1)
    rng = np.random.default_rng(2)
    dnear = np.abs(rng.standard_normal(12)).astype(np.float32) * 3
    w = np.ones(12, dtype=np.float32)
    (got,) = model.build_g_mean(x, y, dnear, w)
    want = ref.build_g_ref(x, y, dnear, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_build_g_is_nonpositive():
    """g = min(d - dnear, 0) <= 0 always (adding a medoid never hurts)."""
    x, y = _data(seed=3)
    dnear = np.full(12, 0.5, dtype=np.float32)
    w = np.ones(12, dtype=np.float32)
    (got,) = model.build_g_mean(x, y, dnear, w)
    assert (np.asarray(got) <= 1e-6).all()


def test_build_g_padding_mask():
    """Padded reference rows (w=0) must not affect the result."""
    x, y = _data(t=4, r=8, d=8, seed=4)
    dnear = np.abs(np.random.default_rng(5).standard_normal(8)).astype(np.float32)
    w_full = np.ones(8, dtype=np.float32)
    (full,) = model.build_g_mean(x, y, dnear, w_full)

    # Append garbage padding rows with w=0; mean must be unchanged.
    pad = np.full((4, 8), 1e6, dtype=np.float32)
    y_pad = np.concatenate([y, pad])
    dnear_pad = np.concatenate([dnear, np.zeros(4, dtype=np.float32)])
    w_pad = np.concatenate([w_full, np.zeros(4, dtype=np.float32)])
    (padded,) = model.build_g_mean(x, y_pad, dnear_pad, w_pad)
    np.testing.assert_allclose(np.asarray(full), np.asarray(padded), **TOL)


def test_build_g_infinite_dnear_reduces_to_mean_negative_distance():
    """With no medoids yet (dnear=+inf surrogate), g == d - BIG clipped: the
    driver uses a large finite sentinel; check monotonicity instead: smaller
    mean distance => smaller (more negative) g."""
    x, y = _data(t=6, r=16, d=8, seed=6)
    big = np.full(16, 1e9, dtype=np.float32)
    w = np.ones(16, dtype=np.float32)
    (g,) = model.build_g_mean(x, y, big, w)
    d = np.asarray(ref.l2_ref(x, y)).mean(axis=1)
    order_g = np.argsort(np.asarray(g))
    order_d = np.argsort(d - 1e9)
    assert (order_g == order_d).all()


# ---------------------------------------------------------------------------
# swap_delta (FastPAM1 decomposition, Eq. 12)
# ---------------------------------------------------------------------------


def swap_delta_naive(x, y, d1, d2, near_is_m, w):
    """Direct transcription of Eq. 12, looped."""
    d = np.asarray(ref.l2_ref(x, y))
    k, r = near_is_m.shape
    t = x.shape[0]
    out = np.zeros((k, t), dtype=np.float64)
    for m in range(k):
        for ti in range(t):
            acc = 0.0
            for j in range(r):
                if near_is_m[m, j] > 0.5:
                    g = -d1[j] + min(d2[j], d[ti, j])
                else:
                    g = -d1[j] + min(d1[j], d[ti, j])
                acc += g * w[j]
            out[m, ti] = acc / max(w.sum(), 1.0)
    return out


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_swap_delta_matches_naive(seed):
    rng = np.random.default_rng(seed)
    t, r, d, k = 5, 9, 7, 3
    x = rng.standard_normal((t, d)).astype(np.float32)
    y = rng.standard_normal((r, d)).astype(np.float32)
    d1 = np.abs(rng.standard_normal(r)).astype(np.float32)
    d2 = (d1 + np.abs(rng.standard_normal(r))).astype(np.float32)  # d2 >= d1
    near = np.zeros((k, r), dtype=np.float32)
    near[rng.integers(0, k, size=r), np.arange(r)] = 1.0
    w = np.ones(r, dtype=np.float32)
    (got,) = model.swap_delta(x, y, d1, d2, near, w)
    want = swap_delta_naive(x, y, d1, d2, near, w)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-3)


def test_swap_delta_nonpositive_for_self_swap_identity():
    """Swapping a medoid for a point at distance 0 from it changes nothing:
    candidate == medoid location implies delta ~ 0 for that medoid's arm."""
    rng = np.random.default_rng(7)
    r, d = 8, 4
    y = rng.standard_normal((r, d)).astype(np.float32)
    medoid = y[0:1]
    # one medoid (k=1): every point's nearest medoid is m0
    dmat = np.asarray(ref.l2_ref(medoid, y))[0]
    d1 = dmat.astype(np.float32)
    d2 = np.full(r, 1e6, dtype=np.float32)
    near = np.ones((1, r), dtype=np.float32)
    w = np.ones(r, dtype=np.float32)
    (delta,) = model.swap_delta(medoid, y, d1, d2, near, w)
    # replacing m0 by itself: min(d2, d) with d == d1 --> -d1 + d1 = 0
    np.testing.assert_allclose(np.asarray(delta)[0, 0], 0.0, atol=1e-4)


# ---------------------------------------------------------------------------
# Lowering smoke: every graph jits and lowers to HLO text
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ["l2", "l1", "cosine"])
def test_pairwise_lowers_to_hlo_text(metric):
    from compile import aot

    shapes = model.example_shapes(8, 8, 16)
    lowered = jax.jit(model.pairwise(metric)).lower(*shapes["pairwise"])
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[8,8]" in text  # output block shape appears


def test_build_g_lowers():
    from compile import aot

    shapes = model.example_shapes(8, 16, 8)
    lowered = jax.jit(model.build_g_mean).lower(*shapes["build_g"])
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
