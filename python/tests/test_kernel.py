"""Pallas kernels vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes (including non-default tile divisors) and data
(including adversarial values: zeros, duplicates, large magnitudes); every
kernel output must match ``ref.py`` to float32 tolerance.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pairwise as pk
from compile.kernels import ref

RNG = np.random.default_rng(0)


def rand(t, d, scale=1.0, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return (rng.standard_normal((t, d)) * scale).astype(np.float32)


TOL = dict(rtol=2e-4, atol=2e-4)

METRICS = ["l2", "l1", "cosine"]


# ---------------------------------------------------------------------------
# Fixed-shape smoke tests (fast, deterministic)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", METRICS)
def test_kernel_matches_ref_default_tiles(metric):
    x, y = rand(64, 128, seed=1), rand(128, 128, seed=2)
    got = np.asarray(pk.get_kernel(metric)(x, y))
    want = np.asarray(ref.REF[metric](x, y))
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("metric", METRICS)
def test_kernel_single_tile(metric):
    """Shapes no larger than one tile exercise the min(tb, t) clamping."""
    x, y = rand(3, 5, seed=3), rand(7, 5, seed=4)
    got = np.asarray(pk.get_kernel(metric)(x, y))
    want = np.asarray(ref.REF[metric](x, y))
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("metric", METRICS)
def test_kernel_multi_d_tiles(metric):
    """D strictly larger than db exercises the accumulation loop."""
    x, y = rand(8, 96, seed=5), rand(16, 96, seed=6)
    got = np.asarray(pk.get_kernel(metric)(x, y, tb=4, rb=8, db=16))
    want = np.asarray(ref.REF[metric](x, y))
    np.testing.assert_allclose(got, want, **TOL)


def test_l2_self_distance_zero():
    x = rand(16, 32, seed=7)
    d = np.asarray(pk.l2_pairwise(x, x, tb=8, rb=8, db=8))
    # The norm-trick (|x|^2+|y|^2-2xy) cancels catastrophically at d ~ 0:
    # fp32 error in d^2 is ~eps*|x|^2, so |d| <~ sqrt(eps)*|x| ~ 1e-2 here.
    assert np.allclose(np.diag(d), 0.0, atol=2e-2)


def test_l2_symmetry():
    x, y = rand(8, 16, seed=8), rand(8, 16, seed=9)
    dxy = np.asarray(pk.l2_pairwise(x, y, tb=4, rb=4, db=4))
    dyx = np.asarray(pk.l2_pairwise(y, x, tb=4, rb=4, db=4))
    np.testing.assert_allclose(dxy, dyx.T, **TOL)


def test_cosine_zero_vector_distance_is_one():
    x = np.zeros((4, 8), dtype=np.float32)
    y = rand(4, 8, seed=10)
    d = np.asarray(pk.cosine_pairwise(x, y, tb=4, rb=4, db=4))
    np.testing.assert_allclose(d, np.ones_like(d), **TOL)


def test_l1_nonnegative_and_triangle():
    x = rand(6, 12, seed=11)
    d = np.asarray(pk.l1_pairwise(x, x, tb=3, rb=3, db=4))
    assert (d >= -1e-4).all()
    n = d.shape[0]
    for i in range(n):
        for j in range(n):
            for k in range(n):
                assert d[i, j] <= d[i, k] + d[k, j] + 1e-3


def test_indivisible_shape_autofits_tiles():
    """Tile sizes auto-shrink to the largest divisor <= the preference, so
    awkward shapes (e.g. d=784 with db=128) still work and stay correct."""
    x, y = rand(10, 7, seed=20), rand(10, 7, seed=21)
    got = np.asarray(pk.l2_pairwise(x, y, tb=4, rb=4, db=4))
    want = np.asarray(ref.l2_ref(x, y))
    np.testing.assert_allclose(got, want, **TOL)
    assert pk.fit_tile(784, 128) == 112  # largest divisor of 784 <= 128
    assert pk.fit_tile(10, 4) == 2
    assert pk.fit_tile(7, 4) == 1


def test_unknown_metric_raises():
    with pytest.raises(ValueError, match="unknown metric"):
        pk.get_kernel("chebyshev")


# ---------------------------------------------------------------------------
# Hypothesis sweeps: random shapes (built from tile multiples) and data
# ---------------------------------------------------------------------------

tile = st.sampled_from([1, 2, 4])
mult = st.integers(min_value=1, max_value=3)


@settings(max_examples=25, deadline=None)
@given(
    metric=st.sampled_from(METRICS),
    tb=tile, rb=tile, db=st.sampled_from([2, 4]),
    mt=mult, mr=mult, md=mult,
    scale=st.sampled_from([1e-2, 1.0, 1e2]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_random(metric, tb, rb, db, mt, mr, md, scale, seed):
    t, r, d = tb * mt, rb * mr, db * md
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((t, d)) * scale).astype(np.float32)
    y = (rng.standard_normal((r, d)) * scale).astype(np.float32)
    got = np.asarray(pk.get_kernel(metric)(x, y, tb=tb, rb=rb, db=db))
    want = np.asarray(ref.REF[metric](x, y))
    # cosine of tiny vectors is ill-conditioned; loosen for the small scale
    tol = dict(rtol=5e-3, atol=5e-3) if scale < 1 and metric == "cosine" else TOL
    np.testing.assert_allclose(got, want, **tol)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    dup=st.booleans(),
)
def test_l2_duplicate_points(seed, dup):
    """Duplicated rows must yield exactly-matching distance rows."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((8, 16)).astype(np.float32)
    if dup:
        x[1] = x[0]
    y = rng.standard_normal((8, 16)).astype(np.float32)
    d = np.asarray(pk.l2_pairwise(x, y, tb=4, rb=4, db=4))
    if dup:
        np.testing.assert_allclose(d[0], d[1], rtol=1e-6, atol=1e-6)
    want = np.asarray(ref.l2_ref(x, y))
    np.testing.assert_allclose(d, want, **TOL)
