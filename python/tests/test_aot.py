"""AOT pipeline tests: manifest schema, idempotence, artifact contents."""

import json
import os

import pytest

from compile import aot


def test_configs_have_unique_names():
    names = [c["name"] for c in aot.CONFIGS]
    assert len(names) == len(set(names))


def test_configs_schema():
    for cfg in aot.CONFIGS:
        assert cfg["kind"] in ("pairwise", "build_g", "swap_delta")
        assert cfg["metric"] in ("l2", "l1", "cosine")
        assert cfg["t"] > 0 and cfg["r"] > 0 and cfg["d"] > 0
        if cfg["kind"] == "swap_delta":
            assert cfg.get("k", 0) > 0


def test_lower_single_artifact(tmp_path):
    """Lower the cheapest config end-to-end and validate output files."""
    name = "pairwise_l2_64x128x16"
    rc = aot.main(["--out", str(tmp_path), "--only", name, "--force"])
    assert rc == 0
    hlo = tmp_path / f"{name}.hlo.txt"
    assert hlo.exists()
    text = hlo.read_text()
    assert text.startswith("HloModule")
    assert "f32[64,128]" in text  # the [T, R] output
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == 1
    entry = manifest["artifacts"][0]
    assert entry["name"] == name
    assert entry["file"] == f"{name}.hlo.txt"
    assert (entry["t"], entry["r"], entry["d"]) == (64, 128, 16)


def test_idempotence(tmp_path):
    """Second run without --force is a no-op when the manifest is fresh."""
    name = "pairwise_l2_64x128x16"
    assert aot.main(["--out", str(tmp_path), "--only", name, "--force"]) == 0
    manifest = tmp_path / "manifest.json"
    # Make the manifest strictly newer than all sources.
    future = aot.newest_source_mtime() + 10
    os.utime(manifest, (future, future))
    before = manifest.stat().st_mtime
    assert aot.main(["--out", str(tmp_path), "--only", name]) == 0
    assert manifest.stat().st_mtime == before


def test_unknown_kind_raises():
    with pytest.raises(ValueError, match="unknown artifact kind"):
        aot.lower_config(
            {"kind": "nope", "metric": "l2", "t": 4, "r": 4, "d": 4, "name": "x"}
        )
